"""Serving benchmarks: fused decode loop + continuous-batching scheduler.

Emits ``name,us_per_call,derived`` rows (harness contract). Two experiments:

* **fused vs stepwise** (``serve_fused_*`` / ``serve_stepwise_*``): the same
  greedy generation through the single-dispatch ``lax.scan`` path vs the seed
  per-token host loop — the PR-1 decode-fusion win.
* **continuous vs static** (``serve_continuous_*`` / ``serve_static_*``): an
  open-loop Poisson-arrival workload (heterogeneous prompt lengths and
  ``max_new``) served by the :class:`ContinuousScheduler` slot pool vs static
  grouped ``serve()`` (a group must finish before the next starts). Arrival
  rate is calibrated to ``--util`` of the continuous path's measured
  closed-loop capacity; rows report tokens/sec over the makespan and
  p50/p99 request latency (arrival → completion). The static path burns
  decode steps as dead padding whenever a group mixes ``max_new`` budgets —
  the continuous pool refills those rows instead, which is where the
  throughput gap comes from.
* **paged vs contiguous KV** (``serve_paged_*`` / ``serve_contig_*``): a
  shared-system-prompt workload (every request = one common system prompt
  + a unique tail) served by the continuous scheduler twice — over the
  paged block pool with prefix caching, and over the contiguous
  ``[max_batch, slots]`` layout. Sustained tokens/sec is the closed-loop
  saturated capacity (``cap_tok_s``, best-of-3 — stable under OS noise);
  p50/p99 request latencies come from an open-loop Poisson trace on
  identical arrivals at ``--util`` of contiguous capacity. Rows also
  report the provisioned KV footprint in bytes (block pool + block tables
  + prefix-registry masters vs contiguous rows) and block-pool occupancy.
  The memory win comes from allocating only the blocks a row touches and
  storing the shared prefix once; the throughput win from admitting
  hash-matched requests with a suffix-only prefill.
* **chaos** (``serve_chaos_*``): the fault-tolerance gate — one Poisson
  trace with seeded NaN-logit injections into live decode rows, an
  allocator-drought admission round, a stalled flush under the watchdog,
  and ~10% client cancellations. Reports goodput (COMPLETED tokens over
  the makespan), the completion-rate-by-status breakdown, and
  detection→recovery latency of the quarantine + precision-fallback path;
  asserts the block pool drains to zero with the paranoid per-step audit
  clean and that a recovered request's tokens are identical to a clean
  accuracy-critical run.
* **crash restart** (``serve_crash_*``): the durability gate — the same
  closed-loop workload served uninterrupted and through a mid-run kill +
  :func:`repro.serving.durability.recover` cycle (write-ahead journal +
  periodic live-state checkpoints). Reports recovery latency and goodput
  through the restart vs the uninterrupted capacity; asserts every
  delivered stream is token-identical to the twin and the pool drains
  clean after the post-restart run.

CPU interpret-path numbers: what they measure is the *runtime overhead around
the kernels* (dispatch count, host syncs, cache copies, dead-step density),
which is exactly the adaptive-inference tax the paper says must be
negligible. TPU numbers come from deployment.

  PYTHONPATH=src python benchmarks/serving_bench.py [--quick|--smoke]
      [--iters N] [--util U] [--n-req N] [--seed S] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig
from repro.serving.faults import FaultSchedule
from repro.serving.scheduler import ContinuousScheduler as _ContinuousScheduler

# --paranoid: run BlockAllocator.check() every step in EVERY bench's
# scheduler (the chaos bench always audits; this extends it fleet-wide).
PARANOID = False


def ContinuousScheduler(srv, **kw):
    kw.setdefault("paranoid", PARANOID)
    return _ContinuousScheduler(srv, **kw)

# (batch, prompt_len, max_new, kv_bits) — batch ≥ 4 / new ≥ 32 are the
# acceptance points for the fused-loop speedup
POINTS = [
    (1, 16, 32, 16),
    (4, 16, 32, 16),
    (4, 16, 32, 8),
    (4, 64, 64, 16),
    (8, 32, 64, 16),
    (8, 32, 64, 8),
]
QUICK_POINTS = [(4, 16, 32, 16), (4, 16, 32, 8)]


def _build(arch: str = "granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


def _time(fn, iters: int) -> float:
    fn()                                  # warmup: compile outside the clock
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def bench_point(cfg, params, eng, b, s, new, kv_bits, iters: int):
    scfg = ServingConfig(slots=s + new + 8, kv_bits=kv_bits, max_batch=b)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (b, s)).astype(np.int32)

    t_fused = _time(lambda: srv.generate(prompts, new), iters)
    t_step = _time(lambda: srv.generate_stepwise(prompts, new), iters)

    tag = f"b{b}_p{s}_n{new}_kv{kv_bits}"
    toks = b * new
    tok_s_fused = toks / t_fused
    tok_s_step = toks / t_step
    speedup = t_step / t_fused
    rows = [
        (f"serve_fused_{tag}", t_fused * 1e6,
         f"tok_s={tok_s_fused:.0f};speedup_vs_stepwise={speedup:.2f}x"),
        (f"serve_stepwise_{tag}", t_step * 1e6,
         f"tok_s={tok_s_step:.0f};dispatches_per_call={new}"),
    ]
    return rows, speedup


def run(points=None, iters: int = 3) -> list[tuple]:
    cfg, params, eng = _build()
    rows: list[tuple] = []
    for b, s, new, kv in (points or POINTS):
        point_rows, _ = bench_point(cfg, params, eng, b, s, new, kv, iters)
        rows.extend(point_rows)
    return rows


# ---------------------------------------------------------------------------
# continuous batching: open-loop Poisson workload
# ---------------------------------------------------------------------------

# discrete length/budget menus keep the static path's executable count small
# (group maxlen / max(max_new) are drawn from these sets), so the timed runs
# measure serving, not compilation. The long-tailed max_new menu is the
# canonical continuous-batching traffic shape: most requests are short, a few
# run long — a static group burns max(max_new) steps for every row.
PROMPT_LENS = (8, 16)
MAX_NEWS = (4, 8, 16, 128)


def _workload(cfg, n_req: int, seed: int,
              lens=PROMPT_LENS, news=MAX_NEWS) -> list[Request]:
    """Round-robin over the length/budget menus (prompt contents seeded):
    composition is deterministic — a reproducible trace — so run-to-run
    variance comes from arrival times and the machine, not from which
    requests happened to land in the same static group."""
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, cfg.vocab,
                                        lens[i % len(lens)]).astype(np.int32),
                    max_new=news[i % len(news)])
            for i in range(n_req)]


def _percentiles(lat: np.ndarray) -> tuple[float, float]:
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def _run_continuous(srv, reqs, arrivals, quantum):
    n = len(reqs)
    sched = ContinuousScheduler(srv, quantum=quantum, record_events=False)
    done_t = np.zeros((n,))
    n_done, nxt = 0, 0
    t0 = time.perf_counter()
    while n_done < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            sched.submit(reqs[nxt])
            nxt += 1
        busy = sched.step()                # admit + segment + flush
        if not busy and nxt < n:           # idle until the next arrival
            time.sleep(min(1e-3, max(0.0, arrivals[nxt] - now)))
        for rid, _res in sched.poll_completed():
            done_t[rid] = time.perf_counter() - t0
            n_done += 1
    return done_t, time.perf_counter() - t0


def _run_static(srv, reqs, arrivals, max_batch):
    n = len(reqs)
    done_t = np.zeros((n,))
    n_done, nxt = 0, 0
    backlog: list[int] = []
    t0 = time.perf_counter()
    while n_done < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            backlog.append(nxt)
            nxt += 1
        if backlog:                        # serve the oldest arrivals as one
            group, backlog = backlog[:max_batch], backlog[max_batch:]
            srv.serve([reqs[i] for i in group])
            t_done = time.perf_counter() - t0
            for i in group:
                done_t[i] = t_done
            n_done += len(group)
        elif nxt < n:
            time.sleep(min(1e-3, max(0.0, arrivals[nxt] - now)))
    return done_t, time.perf_counter() - t0


def bench_poisson(cfg, params, eng, *, n_req: int = 48, util: float = 0.95,
                  max_batch: int = 8, quantum: int = 8, seed: int = 0,
                  lens=PROMPT_LENS, news=MAX_NEWS) -> list[tuple]:
    scfg = ServingConfig(slots=max(lens) + max(news) + 8, max_batch=max_batch)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    reqs = _workload(cfg, n_req, seed, lens, news)
    total_tokens = sum(r.max_new for r in reqs)

    # warm every admission-wave executable the open-loop run can hit: wave
    # row-counts bucket to powers of two and prompts to pow2 length buckets,
    # so cover (1,2,4,...,max_batch) × lens with throwaway 2-token requests
    w = 1
    while w <= max_batch:
        for length in lens:
            warm = ContinuousScheduler(srv, quantum=quantum)
            for _ in range(w):
                warm.submit(Request(tokens=np.ones(length, np.int32),
                                    max_new=2))
            warm.run()
        w *= 2
    # closed-loop warm pass, then a second run measures the continuous
    # capacity that sets the arrival rate — calibration excludes compile time
    for _ in range(2):
        sched = ContinuousScheduler(srv, quantum=quantum)
        for r in reqs:
            sched.submit(r)
        t0 = time.perf_counter()
        sched.run()
        cap_tok_s = total_tokens / (time.perf_counter() - t0)
    # warm every static executable the open-loop run can hit
    for length in lens:
        for mn in news:
            srv.serve([Request(tokens=np.ones(length, np.int32), max_new=mn)
                       for _ in range(max_batch)])

    lam = util * cap_tok_s / (total_tokens / n_req)     # requests / second
    arrivals = np.cumsum(np.random.default_rng(seed + 1)
                         .exponential(1.0 / lam, n_req))

    cont_t, cont_mk = _run_continuous(srv, reqs, arrivals, quantum)
    stat_t, stat_mk = _run_static(srv, reqs, arrivals, max_batch)

    c50, c99 = _percentiles((cont_t - arrivals) * 1e3)
    s50, s99 = _percentiles((stat_t - arrivals) * 1e3)
    speedup = stat_mk / cont_mk
    tag = f"b{max_batch}_q{quantum}_n{n_req}_u{util:g}"
    return [
        (f"serve_continuous_{tag}", cont_mk * 1e6,
         f"tok_s={total_tokens / cont_mk:.0f};p50_ms={c50:.1f};"
         f"p99_ms={c99:.1f};speedup_vs_static={speedup:.2f}x"),
        (f"serve_static_{tag}", stat_mk * 1e6,
         f"tok_s={total_tokens / stat_mk:.0f};p50_ms={s50:.1f};"
         f"p99_ms={s99:.1f};offered_tok_s={util * cap_tok_s:.0f}"),
    ]


# ---------------------------------------------------------------------------
# paged vs contiguous KV: shared-system-prompt Poisson trace
# ---------------------------------------------------------------------------

def _shared_prefix_workload(cfg, n_req: int, sys_len: int, tail_len: int,
                            max_new: int, seed: int) -> list[Request]:
    """One shared system prompt + a unique per-request tail (the canonical
    multi-tenant chat shape: identical instructions, divergent users)."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab, sys_len).astype(np.int32)
    return [Request(tokens=np.concatenate(
                [sys_prompt,
                 rng.integers(0, cfg.vocab, tail_len).astype(np.int32)]),
                    max_new=max_new)
            for _ in range(n_req)]


def _warm_sched(srv, reqs, quantum):
    """Compile every executable a scheduler run over ``reqs`` can hit.

    Two pow2 wave-size sweeps: one of *distinct* prompts (same shape, fresh
    contents each wave → registry misses → every COLD-wave row bucket
    compiles) and one of repeats of ``reqs[0]`` after it has been
    registered (→ every SHARED-wave row bucket). A paged timed run starts
    with an empty registry, so both kinds of wave occur and an unwarmed
    one would drop an XLA compile inside the timed region."""
    warm = ContinuousScheduler(srv, quantum=quantum, record_events=False)
    rng = np.random.default_rng(2**31 - 1)
    length = len(reqs[0].tokens)
    vocab = int(reqs[0].tokens.max()) + 1
    w = 1
    while w <= warm.n_slots:
        for _ in range(w):                      # cold waves: unique prompts
            warm.submit(Request(tokens=rng.integers(0, vocab, length)
                                .astype(np.int32), max_new=2))
        warm.run()
        w *= 2
    warm.submit(Request(tokens=reqs[0].tokens.copy(), max_new=2))
    warm.run()                                  # registers the shared prefix
    w = 1
    while w <= warm.n_slots:
        for _ in range(w):                      # shared waves: repeats
            warm.submit(Request(tokens=reqs[0].tokens.copy(), max_new=2))
        warm.run()
        w *= 2


def _run_sched_trace(srv, reqs, arrivals, quantum, paranoid=None):
    """Open-loop run of one (pre-warmed) ContinuousScheduler over a fixed
    arrival trace; returns (completion times, makespan, paged_stats).
    ``paranoid=False`` opts a timing-comparison bench out of the
    ``--paranoid`` sweep (the per-step audit is host-side O(pool) work
    that lands asymmetrically on preemption-heavy runs)."""
    kw = {} if paranoid is None else {"paranoid": paranoid}
    sched = ContinuousScheduler(srv, quantum=quantum, record_events=False,
                                **kw)
    n = len(reqs)
    done_t = np.zeros((n,))
    n_done, nxt = 0, 0
    t0 = time.perf_counter()
    while n_done < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            sched.submit(reqs[nxt])
            nxt += 1
        busy = sched.step()
        if not busy and nxt < n:
            time.sleep(min(1e-3, max(0.0, arrivals[nxt] - now)))
        for rid, _res in sched.poll_completed():
            done_t[rid] = time.perf_counter() - t0
            n_done += 1
    mk = time.perf_counter() - t0
    stats = sched.paged_stats()
    if sched.registry is not None:
        stats["kv_bytes"] += stats.get("registry_bytes", 0)
    return done_t, mk, stats


def bench_shared_prefix(cfg, params, eng, *, n_req: int = 24,
                        sys_len: int = 64, tail_len: int = 8,
                        max_new: int = 8, max_batch: int = 8,
                        quantum: int = 8, block_size: int = 16,
                        util: float = 0.8,
                        seed: int = 0) -> tuple[list[tuple], dict]:
    """Paged+prefix-cache vs contiguous slot pool on the same Poisson trace.

    The paged pool is provisioned at ``shared prefix blocks + max_batch ×
    private blocks per row + one cold row`` — the working set the workload
    actually needs — while the contiguous pool must reserve ``max_batch ×
    slots`` regardless. Both serve identical arrivals calibrated to
    ``util`` of the contiguous path's closed-loop capacity.
    """
    slots = sys_len + tail_len + max_new + block_size
    bs = block_size
    blocks_row = -(-(sys_len + tail_len + max_new) // bs)
    shared_blocks = sys_len // bs
    private_row = blocks_row - shared_blocks
    pool_blocks = shared_blocks + max_batch * private_row + blocks_row
    scfg_paged = ServingConfig(slots=slots, max_batch=max_batch,
                               block_size=bs, pool_blocks=pool_blocks,
                               paged_kv=True, prefix_cache=True)
    scfg_contig = ServingConfig(slots=slots, max_batch=max_batch,
                                paged_kv=False)
    srv_paged = AdaptiveServer(cfg, params, eng, scfg_paged)
    srv_contig = AdaptiveServer(cfg, params, eng, scfg_contig)
    reqs = _shared_prefix_workload(cfg, n_req, sys_len, tail_len, max_new,
                                   seed)
    total_tokens = n_req * max_new

    _warm_sched(srv_contig, reqs, quantum)     # compile before any timing
    _warm_sched(srv_paged, reqs, quantum)

    def capacity(srv):
        # closed-loop sustained capacity: every request queued up front, the
        # pool stays saturated; best-of-3 filters OS noise (the open-loop
        # makespans at CPU-smoke scale are dominated by it)
        best = None
        for _ in range(3):
            sched = ContinuousScheduler(srv, quantum=quantum,
                                        record_events=False)
            for r in reqs:
                sched.submit(r)
            t0 = time.perf_counter()
            sched.run()
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return total_tokens / best

    cap_con = capacity(srv_contig)              # calibrates the Poisson rate
    cap_pag = capacity(srv_paged)
    lam = util * cap_con / max_new
    arrivals = np.cumsum(np.random.default_rng(seed + 1)
                         .exponential(1.0 / lam, n_req))

    pag_t, pag_mk, pag_stats = _run_sched_trace(srv_paged, reqs, arrivals,
                                                quantum)
    con_t, con_mk, con_stats = _run_sched_trace(srv_contig, reqs, arrivals,
                                                quantum)
    p50, p99 = _percentiles((pag_t - arrivals) * 1e3)
    c50, c99 = _percentiles((con_t - arrivals) * 1e3)
    mem_saving = 1.0 - pag_stats["kv_bytes"] / con_stats["kv_bytes"]
    speedup = cap_pag / cap_con
    tag = f"b{max_batch}_sys{sys_len}_t{tail_len}_n{max_new}_r{n_req}"
    rows = [
        (f"serve_paged_{tag}", pag_mk * 1e6,
         f"cap_tok_s={cap_pag:.0f};p50_ms={p50:.1f};"
         f"p99_ms={p99:.1f};kv_bytes={pag_stats['kv_bytes']};"
         f"kv_saving={mem_saving * 100:.0f}%;"
         f"peak_blocks={pag_stats['peak_used_blocks']}/"
         f"{pag_stats['pool_blocks']};"
         f"prefix_hits={pag_stats.get('registry_hits', 0)};"
         f"speedup_vs_contig={speedup:.2f}x"),
        (f"serve_contig_{tag}", con_mk * 1e6,
         f"cap_tok_s={cap_con:.0f};p50_ms={c50:.1f};"
         f"p99_ms={c99:.1f};kv_bytes={con_stats['kv_bytes']};"
         f"offered_tok_s={util * cap_con:.0f}"),
    ]
    return rows, {"paged": pag_stats, "contiguous": con_stats,
                  "kv_saving_frac": mem_saving,
                  "capacity_tok_s": {"paged": cap_pag, "contiguous": cap_con},
                  "speedup_vs_contig": speedup}


# ---------------------------------------------------------------------------
# chunked prefill: long-prompt Poisson trace (admission-wave latency spike)
# ---------------------------------------------------------------------------

def _long_prompt_workload(cfg, n_req: int, short_len: int, long_len: int,
                          long_every: int, max_new: int,
                          seed: int) -> list[Request]:
    """Mostly short decode-heavy requests with a periodic long prompt — the
    shape that makes monolithic admission waves hurt: every live row stalls
    for the long prefill, spiking the p99 of the *short* requests."""
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(
                0, cfg.vocab,
                long_len if i % long_every == long_every - 1 else short_len)
                .astype(np.int32), max_new=max_new)
            for i in range(n_req)]


def _warm_long(srv, reqs, quantum):
    """Compile every executable the long-prompt trace can hit (cold waves
    at both length buckets, plus — on a chunked server — every
    chunk-continuation (suffix, prefix) bucket a long prompt walks
    through), so the timed open-loop runs measure serving, not XLA."""
    lens = sorted({len(r.tokens) for r in reqs})
    vocab = int(max(int(r.tokens.max()) for r in reqs)) + 1
    rng = np.random.default_rng(2**31 - 5)
    w = 1
    while w <= srv.scfg.max_batch:
        for length in lens:
            warm = ContinuousScheduler(srv, quantum=quantum,
                                       record_events=False)
            for _ in range(w):
                warm.submit(Request(tokens=rng.integers(0, vocab, length)
                                    .astype(np.int32), max_new=2))
            warm.run()
        w *= 2
    warm = ContinuousScheduler(srv, quantum=quantum, record_events=False)
    for _ in range(2):            # two long prompts: chunk waves of 1 and 2
        warm.submit(Request(tokens=rng.integers(0, vocab, max(lens))
                            .astype(np.int32), max_new=2))
    warm.run()


def bench_chunked_prefill(cfg, params, eng, *, n_req: int = 18,
                          short_len: int = 8, long_len: int = 1024,
                          long_every: int = 6, max_new: int = 8,
                          max_batch: int = 4, quantum: int = 2,
                          chunk: int = 256, util: float = 0.7,
                          seed: int = 0) -> tuple[list[tuple], dict]:
    """Chunked vs monolithic admission on the same long-prompt Poisson trace.

    Identical paged servers except ``prefill_chunk``; identical arrivals
    calibrated to ``util`` of the *unchunked* path's closed-loop capacity;
    best-of-3 per-request latencies on each backend (same de-noising as the
    capacity measurement). The headline metric is the **short-request**
    (interactive-class) p99: a monolithic long-prompt wave stalls every
    live row for the whole prefill, while chunks interleave with decode
    segments — the long request itself finishes a little later, the
    traffic behind it much sooner. Overall-percentile numbers are reported
    alongside.
    """
    slots = long_len + max_new + 16
    common = dict(slots=slots, max_batch=max_batch, block_size=16,
                  paged_kv=True, prefix_cache=False)
    srv_mono = AdaptiveServer(cfg, params, eng, ServingConfig(**common))
    srv_chunk = AdaptiveServer(cfg, params, eng,
                               ServingConfig(prefill_chunk=chunk, **common))
    reqs = _long_prompt_workload(cfg, n_req, short_len, long_len, long_every,
                                 max_new, seed)
    total_tokens = n_req * max_new
    _warm_long(srv_mono, reqs, quantum)
    _warm_long(srv_chunk, reqs, quantum)

    def capacity(srv):
        best = None
        for _ in range(2):
            sched = ContinuousScheduler(srv, quantum=quantum,
                                        record_events=False)
            for r in reqs:
                sched.submit(r)
            t0 = time.perf_counter()
            sched.run()
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return total_tokens / best

    cap_mono = capacity(srv_mono)
    lam = util * cap_mono / max_new
    arrivals = np.cumsum(np.random.default_rng(seed + 1)
                         .exponential(1.0 / lam, n_req))

    def best_trace(srv, repeats=3):
        # identical arrivals, best-of-N per request: the structural latency
        # each backend imposes, with CPU-box OS noise filtered the same way
        # the capacity measurement filters it
        lat = mk = None
        for _ in range(repeats):
            t, m, _ = _run_sched_trace(srv, reqs, arrivals, quantum)
            lat = t if lat is None else np.minimum(lat, t)
            mk = m if mk is None else min(mk, m)
        return lat, mk

    chk_t, chk_mk = best_trace(srv_chunk)
    mon_t, mon_mk = best_trace(srv_mono)
    short = np.asarray([len(r.tokens) == short_len for r in reqs])
    c50, c99 = _percentiles((chk_t - arrivals)[short] * 1e3)
    m50, m99 = _percentiles((mon_t - arrivals)[short] * 1e3)
    ca50, ca99 = _percentiles((chk_t - arrivals) * 1e3)
    ma50, ma99 = _percentiles((mon_t - arrivals) * 1e3)
    tag = f"b{max_batch}_long{long_len}_c{chunk}_n{n_req}"
    rows = [
        (f"serve_chunked_{tag}", chk_mk * 1e6,
         f"tok_s={total_tokens / chk_mk:.0f};p50_short_ms={c50:.1f};"
         f"p99_short_ms={c99:.1f};p99_all_ms={ca99:.1f};"
         f"p99_short_vs_mono={c99 / m99:.2f}x"),
        (f"serve_monolithic_{tag}", mon_mk * 1e6,
         f"tok_s={total_tokens / mon_mk:.0f};p50_short_ms={m50:.1f};"
         f"p99_short_ms={m99:.1f};p99_all_ms={ma99:.1f};"
         f"offered_tok_s={util * cap_mono:.0f}"),
    ]
    return rows, {"p50_short_ms": {"chunked": c50, "monolithic": m50},
                  "p99_short_ms": {"chunked": c99, "monolithic": m99},
                  "p99_all_ms": {"chunked": ca99, "monolithic": ma99},
                  "makespan_s": {"chunked": chk_mk, "monolithic": mon_mk},
                  "chunk_tokens": chunk, "long_len": long_len,
                  "p99_short_improvement": 1.0 - c99 / m99}


# ---------------------------------------------------------------------------
# priority classes + preemption: mixed-class Poisson trace vs FIFO
# ---------------------------------------------------------------------------

def _mixed_class_workload(cfg, n_saver: int, n_crit: int, saver_len: int,
                          crit_len: int, saver_new: int, crit_new: int,
                          seed: int):
    """Saver-class decode hogs + sparse critical requests — the contention
    shape priority scheduling exists for: under FIFO a critical arrival
    queues behind every earlier saver draining its whole budget; under the
    priority policy it jumps the queue and (with preemption) evicts a
    saver row instead."""
    rng = np.random.default_rng(seed)
    savers = [Request(tokens=rng.integers(0, cfg.vocab, saver_len)
                      .astype(np.int32), max_new=saver_new, priority=1)
              for _ in range(n_saver)]
    crits = [Request(tokens=rng.integers(0, cfg.vocab, crit_len)
                     .astype(np.int32), max_new=crit_new, priority=0)
             for _ in range(n_crit)]
    return savers, crits


def _ledger_exact_under_preemption(cfg, params, eng, scfg, quantum: int,
                                   seed: int) -> None:
    """The stepwise-oracle exactness gate, with preemption in the mix: a
    tiny closed-loop run that provably preempts, whose event log must
    replay through a fresh manager to the same profiles and ledger, and
    whose total billed inferences equal Σ(max_new) — suspend/resume bills
    nothing."""
    def manager():
        stats = [ProfileStats(n, a, e, 1e-3) for n, a, e in [
            ("hi", 0.99, 4.0), ("mid", 0.97, 2.0), ("lo", 0.95, 1.0)]]
        return ProfileManager(stats, accuracy_target=0.985,
                              accuracy_floor=0.90, budget_j=500.0,
                              low_energy=0.5)

    mgr = manager()
    srv = AdaptiveServer(cfg, params, eng, scfg, manager=mgr)
    sched = ContinuousScheduler(srv, quantum=quantum, record_events=True)
    rng = np.random.default_rng(seed + 7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, 9).astype(np.int32),
                    max_new=12, priority=1) for _ in range(2)]
    for r in reqs:
        sched.submit(r)
    sched.step()
    sched.step()
    crit = Request(tokens=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                   max_new=3, priority=0)
    reqs.append(crit)
    sched.submit(crit)
    while sched.step():
        pass
    assert sched.preemptions >= 1, "scenario failed to preempt"
    oracle = manager()
    for pid, n_rows, critical in sched.events:
        assert oracle.select(accuracy_critical=critical) == pid, \
            "ledger replay diverged from the stepwise oracle"
        oracle.account(pid, n_rows)
    assert abs(oracle.spent_j - mgr.spent_j) < 1e-9
    billed = sum(n for _, n, _ in sched.events)
    assert billed == sum(r.max_new for r in reqs), \
        f"billed {billed} != {sum(r.max_new for r in reqs)} " \
        f"(suspend/resume must bill nothing)"


def bench_priority(cfg, params, eng, *, n_saver: int = 12, n_crit: int = 4,
                   saver_len: int = 12, crit_len: int = 6,
                   saver_new: int = 48, crit_new: int = 4,
                   max_batch: int = 2, quantum: int = 4,
                   overload: float = 3.0, seed: int = 0,
                   min_speedup: float = 0.0) -> tuple[list[tuple], dict]:
    """Priority classes + preemption vs FIFO on the same mixed-class trace.

    Identical paged servers except the scheduling policy; identical
    arrivals; best-of-3 per-request latencies (the usual CPU de-noising).
    The saver stream arrives Poisson at ``overload``× the measured
    closed-loop capacity — sustained contention, the regime priority
    scheduling exists for — and the sparse critical stream arrives Poisson
    inside the saver busy period. The headline metric is the
    **critical-class p99**: under FIFO a critical arrival queues behind
    every earlier saver draining its ``saver_new``-token budget; the
    priority policy admits it first and preemption evicts a saver row when
    the pool is full (the saver resumes bit-exactly later, paying only the
    suspend/resume overhead — its throughput degrades gracefully, which
    the saver-class tokens/sec ratio reports). ``min_speedup`` > 0 asserts
    the critical-p99 improvement factor.
    """
    slots = saver_len + saver_new + 16
    common = dict(slots=slots, max_batch=max_batch, block_size=16,
                  paged_kv=True, prefix_cache=False)
    srv_fifo = AdaptiveServer(cfg, params, eng, ServingConfig(**common))
    srv_prio = AdaptiveServer(cfg, params, eng,
                              ServingConfig(priority_classes=2,
                                            preemption=True, **common))
    savers, crits = _mixed_class_workload(cfg, n_saver, n_crit, saver_len,
                                          crit_len, saver_new, crit_new,
                                          seed)
    saver_tokens = n_saver * saver_new
    total_tokens = saver_tokens + n_crit * crit_new

    for srv in (srv_fifo, srv_prio):
        # cold waves at both length buckets × pow2 row counts; the resume
        # wave's (prefix-bucket) executables compile on first preemption —
        # best-of-3 washes those out like every other compile
        rng = np.random.default_rng(2**31 - 9)
        w = 1
        while w <= max_batch:
            for length in (saver_len, crit_len):
                warm = ContinuousScheduler(srv, quantum=quantum,
                                           record_events=False)
                for _ in range(w):
                    warm.submit(Request(
                        tokens=rng.integers(0, cfg.vocab, length)
                        .astype(np.int32), max_new=2))
                warm.run()
            w *= 2

    def capacity(srv):
        best = None
        for _ in range(2):
            # paranoid=False: cap_fifo calibrates the overload arrival
            # rate the p99 assertion depends on — keep it audit-free
            sched = ContinuousScheduler(srv, quantum=quantum,
                                        record_events=False, paranoid=False)
            for r in savers:
                sched.submit(r)
            t0 = time.perf_counter()
            sched.run()
            best = min(filter(None, (best, time.perf_counter() - t0)))
        return saver_tokens / best

    cap_fifo = capacity(srv_fifo)          # saver-only closed-loop tok/s
    busy_s = saver_tokens / cap_fifo       # saver busy period if alone
    arr_rng = np.random.default_rng(seed + 1)
    lam_s = overload * cap_fifo / saver_new
    arr_savers = np.cumsum(arr_rng.exponential(1.0 / lam_s, n_saver))
    # criticals land inside the (overloaded → deepening) saver backlog:
    # by 0.35·busy the FIFO queue already holds several whole saver
    # budgets, which is exactly the contention the p99 gap measures
    arr_crits = 0.35 * busy_s + np.cumsum(
        arr_rng.exponential(0.4 * busy_s / max(1, n_crit), n_crit))
    order = np.argsort(np.concatenate([arr_savers, arr_crits]),
                       kind="stable")
    allreqs = savers + crits
    reqs = [allreqs[i] for i in order]
    arrivals = np.sort(np.concatenate([arr_savers, arr_crits]))
    crit_mask = np.asarray([r.priority == 0 for r in reqs])

    def best_trace(srv, repeats=3):
        lat = mk = stats = None
        for _ in range(repeats):
            # paranoid=False: the asserted p99 ratio compares a
            # preemption-heavy run against FIFO; the per-step audit would
            # skew exactly that comparison
            t, m, st = _run_sched_trace(srv, reqs, arrivals, quantum,
                                        paranoid=False)
            lat = t if lat is None else np.minimum(lat, t)
            mk = m if mk is None else min(mk, m)
            if stats is None:
                stats = st
            else:
                # preemption counters are per-repeat scheduler state; keep
                # the max so a warmed final repeat that happened to dodge
                # contention can't under-report (or flake the CI assert)
                for k in ("preemptions", "resumes"):
                    stats[k] = max(stats.get(k, 0), st.get(k, 0))
        return lat, mk, stats

    pri_t, pri_mk, pri_stats = best_trace(srv_prio)
    fif_t, fif_mk, fif_stats = best_trace(srv_fifo)
    pc50, pc99 = _percentiles((pri_t - arrivals)[crit_mask] * 1e3)
    fc50, fc99 = _percentiles((fif_t - arrivals)[crit_mask] * 1e3)
    saver_toks = int(sum(r.max_new for r in reqs if r.priority != 0))
    saver_tok_s = {"priority": saver_toks / pri_mk,
                   "fifo": saver_toks / fif_mk}
    speedup = fc99 / pc99
    _ledger_exact_under_preemption(
        cfg, params, eng,
        ServingConfig(priority_classes=2, preemption=True, **common),
        quantum, seed)
    if min_speedup:
        assert speedup >= min_speedup, \
            f"critical p99 {pc99:.1f}ms vs FIFO {fc99:.1f}ms = " \
            f"{speedup:.2f}x < required {min_speedup:g}x"
    tag = f"b{max_batch}_sv{saver_new}x{n_saver}_cr{crit_new}x{n_crit}"
    rows = [
        (f"serve_priority_{tag}", pri_mk * 1e6,
         f"crit_p50_ms={pc50:.1f};crit_p99_ms={pc99:.1f};"
         f"saver_tok_s={saver_tok_s['priority']:.0f};"
         f"preemptions={pri_stats.get('preemptions', 0)};"
         f"resumes={pri_stats.get('resumes', 0)};"
         f"crit_p99_vs_fifo={speedup:.2f}x"),
        (f"serve_fifo_{tag}", fif_mk * 1e6,
         f"crit_p50_ms={fc50:.1f};crit_p99_ms={fc99:.1f};"
         f"saver_tok_s={saver_tok_s['fifo']:.0f};"
         f"offered_saver_tok_s={overload * cap_fifo:.0f}"),
    ]
    info = {"crit_p99_ms": {"priority": pc99, "fifo": fc99},
            "crit_p50_ms": {"priority": pc50, "fifo": fc50},
            "crit_p99_speedup": speedup,
            "saver_tok_s": saver_tok_s,
            "saver_throughput_ratio":
                saver_tok_s["priority"] / saver_tok_s["fifo"],
            "preemptions": pri_stats.get("preemptions", 0),
            "resumes": pri_stats.get("resumes", 0),
            "ledger_exact": True}
    return rows, info


# ---------------------------------------------------------------------------
# chaos: faults + cancellations + precision-fallback recovery under load
# ---------------------------------------------------------------------------

def _chaos_manager() -> ProfileManager:
    """Three-rung ladder pinned to battery-saver mode (``low_energy`` above
    any remaining fraction): non-critical requests run at the floor profile,
    so a precision-fallback escalation to the accuracy target is an
    *observable* profile change — the regime adaptive recovery exists for.
    The huge budget keeps the target rung eligible for the whole trace."""
    stats = [ProfileStats(n, a, e, 1e-3) for n, a, e in [
        ("hi", 0.99, 4.0), ("mid", 0.97, 2.0), ("lo", 0.95, 1.0)]]
    return ProfileManager(stats, accuracy_target=0.985,
                          accuracy_floor=0.90, budget_j=1e9,
                          low_energy=2.0)


def _run_chaos_trace(srv, reqs, arrivals, quantum, faults, cancel_at,
                     retry_budget):
    """Open-loop Poisson trace with the fault schedule armed, the paranoid
    per-step pool audit on, and client cancellations fired from a wall-clock
    schedule (``rid -> cancel time``); returns every terminal result."""
    sched = ContinuousScheduler(srv, quantum=quantum, record_events=False,
                                faults=faults, retry_budget=retry_budget,
                                watchdog_s=1.0, paranoid=True)
    n = len(reqs)
    results: dict = {}
    done_t = np.zeros((n,))
    pending = dict(cancel_at)
    cancelled_eff = 0
    nxt = 0
    t0 = time.perf_counter()
    while len(results) < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            sched.submit(reqs[nxt])
            nxt += 1
        for rid in [r for r, at in pending.items()
                    if r < nxt and at <= now]:
            del pending[rid]
            cancelled_eff += bool(sched.cancel(rid))
        busy = sched.step()
        if not busy and nxt < n:
            time.sleep(min(1e-3, max(0.0, arrivals[nxt] - now)))
        for rid, res in sched.poll_completed():
            results[rid] = res
            done_t[rid] = time.perf_counter() - t0
    mk = time.perf_counter() - t0
    return results, done_t, mk, sched, cancelled_eff


def bench_chaos(cfg, params, eng, *, n_req: int = 24, prompt_len: int = 10,
                max_new: int = 12, max_batch: int = 4, quantum: int = 4,
                util: float = 0.8, cancel_frac: float = 0.10,
                retry_budget: int = 2, p_nan: float = 0.0, seed: int = 0,
                smoke_asserts: bool = False) -> tuple[list[tuple], dict]:
    """Fault-tolerant serving under chaos: one Poisson trace with NaN-logit
    injections into live decode rows, an allocator-drought admission round,
    a flush stall under the watchdog, and ~``cancel_frac`` client
    cancellations — measuring goodput (tokens of COMPLETED requests over
    the makespan), the completion-rate-by-status breakdown, and
    detection→recovery latency for the quarantine + precision-fallback
    path. Two requests are deterministically fault-targeted on their first
    attempt (``p_nan`` adds seeded random injections on top for the full
    bench); the paranoid per-step audit plus a final :meth:`check` prove
    the pool survives with zero leaked blocks.

    ``smoke_asserts`` additionally requires ≥1 successful escalation
    recovery, ≥1 effective cancellation, a clean allocator at exit, and
    that the recovered request's tokens are identical to a clean
    accuracy-critical run of the same prompt — the acceptance criterion
    that fallback output is *correct*, not merely finite.
    """
    bs = 16
    blocks_row = -(-(prompt_len + max_new) // bs)
    scfg = ServingConfig(slots=prompt_len + max_new + bs,
                         max_batch=max_batch, block_size=bs,
                         pool_blocks=(max_batch + 1) * blocks_row,
                         paged_kv=True, prefix_cache=False)
    srv = AdaptiveServer(cfg, params, eng, scfg, manager=_chaos_manager())
    rng = np.random.default_rng(seed)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, prompt_len)
                    .astype(np.int32), max_new=max_new)
            for _ in range(n_req)]
    total_tokens = n_req * max_new

    # cold-wave warm at every pow2 row count, then one mini chaos run that
    # compiles the reap-clear executable and the quarantine-retry admission
    # (registry bypass) before anything is timed
    wrng = np.random.default_rng(2**31 - 11)
    w = 1
    while w <= max_batch:
        warm = ContinuousScheduler(srv, quantum=quantum, record_events=False)
        for _ in range(w):
            warm.submit(Request(tokens=wrng.integers(0, cfg.vocab, prompt_len)
                                .astype(np.int32), max_new=2))
        warm.run()
        w *= 2
    warm = ContinuousScheduler(srv, quantum=quantum, record_events=False,
                               faults=FaultSchedule(seed, nan_at={0: (0,)}),
                               retry_budget=retry_budget)
    for _ in range(2):
        warm.submit(Request(tokens=wrng.integers(0, cfg.vocab, prompt_len)
                            .astype(np.int32), max_new=2))
    warm.cancel(1)
    warm.run()

    def capacity():
        best = None
        for _ in range(2):
            sched = ContinuousScheduler(srv, quantum=quantum,
                                        record_events=False)
            for r in reqs:
                sched.submit(r)
            t0 = time.perf_counter()
            sched.run()
            best = min(filter(None, (best, time.perf_counter() - t0)))
        return total_tokens / best

    cap = capacity()                        # clean closed-loop tok/s
    busy_s = total_tokens / cap
    arr_rng = np.random.default_rng(seed + 1)
    lam = util * cap / max_new
    arrivals = np.cumsum(arr_rng.exponential(1.0 / lam, n_req))

    # two deterministic first-attempt NaN targets (kept out of the cancel
    # set so the recovery path provably fires); p_nan layers seeded random
    # injections on top in the full bench
    targets = (1, n_req // 2)
    faults = FaultSchedule(seed, p_nan=p_nan, max_nan=3,
                           nan_at={t: (0,) for t in targets},
                           alloc_at=(1,), stall_at=(0,), stall_s=0.02)
    crng = np.random.default_rng(seed + 3)
    cancellable = [r for r in range(n_req) if r not in targets]
    n_cancel = min(len(cancellable), max(1, round(cancel_frac * n_req)))
    cancel_rids = sorted(crng.choice(cancellable, size=n_cancel,
                                     replace=False).tolist())
    # first cancel lands AT its arrival (a queued/just-admitted kill is
    # guaranteed effective); the rest land mid-service
    cancel_at = {rid: arrivals[rid] + (0.0 if i == 0 else
                                       float(crng.uniform(0, 0.5 * busy_s)))
                 for i, rid in enumerate(cancel_rids)}

    results, done_t, mk, sched, cancelled_eff = _run_chaos_trace(
        srv, reqs, arrivals, quantum, faults, cancel_at, retry_budget)

    sched.check()                           # final full pool audit
    stats = sched.paged_stats()
    rstats = sched.robustness_stats()
    by_status: dict = {}
    for res in results.values():
        s = str(res["status"].value)
        by_status[s] = by_status.get(s, 0) + 1
    good_toks = sum(len(r["tokens"]) for r in results.values()
                    if r["status"].value == "completed")
    goodput = good_toks / mk
    rec_ms = [1e3 * t for t in rstats["recovery_latency_s"]]
    done_mask = np.asarray([results[r]["status"].value == "completed"
                            for r in range(n_req)])
    lat_ms = (done_t - arrivals)[done_mask] * 1e3
    p50, p99 = _percentiles(lat_ms) if lat_ms.size else (0.0, 0.0)

    # recovered output must match a clean accuracy-critical run exactly:
    # the escalated retry re-prefills from the prompt at the target-bound
    # profile, so tokens are identical — finite AND correct
    identical = None
    recovered_rid = next((r for r in sorted(results)
                          if results[r]["status"].value == "completed"
                          and results[r].get("retries", 0) >= 1), None)
    if recovered_rid is not None:
        clean = ContinuousScheduler(srv, quantum=quantum,
                                    record_events=False)
        clean.submit(Request(tokens=reqs[recovered_rid].tokens.copy(),
                             max_new=max_new, accuracy_critical=True))
        identical = (clean.run()[0]["tokens"]
                     == results[recovered_rid]["tokens"])

    if smoke_asserts:
        assert stats["used_blocks"] == 0, \
            f"leaked {stats['used_blocks']} pool blocks after drain"
        assert rstats["recovered"] >= 1, \
            f"no precision-fallback recovery fired: {rstats}"
        assert cancelled_eff >= 1 and by_status.get("cancelled", 0) >= 1, \
            f"no effective cancellation: {by_status}"
        assert rstats["alloc_injected_rounds"] >= 1, rstats
        assert identical is True, \
            f"recovered rid {recovered_rid} tokens diverge from the clean " \
            f"accuracy-critical run"

    tag = f"b{max_batch}_n{n_req}x{max_new}"
    rows = [(f"serve_chaos_{tag}", mk * 1e6,
             f"goodput_tok_s={goodput:.0f};"
             f"completed={by_status.get('completed', 0)};"
             f"cancelled={by_status.get('cancelled', 0)};"
             f"failed={by_status.get('failed', 0)};"
             f"recovered={rstats['recovered']};"
             f"faults_detected={rstats['faults_detected']};"
             f"mean_recovery_ms="
             f"{(sum(rec_ms) / len(rec_ms)) if rec_ms else 0.0:.1f}")]
    info = {"status_counts": by_status,
            "goodput_tok_s": goodput,
            "delivered_tok_s": sum(len(r["tokens"])
                                   for r in results.values()) / mk,
            "completed_p50_ms": p50, "completed_p99_ms": p99,
            "recovered": rstats["recovered"],
            "recovery_latency_ms": {
                "mean": (sum(rec_ms) / len(rec_ms)) if rec_ms else None,
                "max": max(rec_ms) if rec_ms else None, "n": len(rec_ms)},
            "cancels": {"scheduled": n_cancel, "effective": cancelled_eff},
            "robustness": rstats,
            "pool": {"used_blocks": stats["used_blocks"],
                     "peak_used_blocks": stats["peak_used_blocks"],
                     "allocator_clean": True},
            "recovered_token_identical": identical}
    return rows, info


# ---------------------------------------------------------------------------
# crash-consistent serving: goodput through a kill + restart (BENCH_9)
# ---------------------------------------------------------------------------

def bench_crash(cfg, params, eng, *, n_req: int = 10, prompt_len: int = 10,
                max_new: int = 8, max_batch: int = 4, quantum: int = 4,
                checkpoint_every: int = 2, seed: int = 0,
                smoke_asserts: bool = False) -> tuple[list[tuple], dict]:
    """Crash-consistent serving: kill the scheduler at a mid-run flush
    boundary and restart (docs/serving.md §Durability, invariant 12).

    One closed-loop workload served twice over the same server: an
    uninterrupted twin (capacity reference), then a journaled run
    (``Durability``: fsync'd write-ahead records + a live-state
    checkpoint every ``checkpoint_every`` rounds) that is abandoned
    mid-run — process death simulated by dropping the scheduler, which
    owns all pool state — and recovered into a fresh scheduler with
    :func:`repro.serving.durability.recover`. Reports **recovery
    latency** (restore + journal replay + chunk re-materialization,
    which is the restart's whole service gap: live rows re-admit through
    the normal resume wave on the first post-restart round) and
    **goodput through restart** (every delivered token over pre-crash +
    recovery + post-crash wall time) against the uninterrupted tok/s.

    ``smoke_asserts`` requires the recovered run to be token-identical
    to the twin on every request, something to have actually survived
    (resumed rows / replayed records), zero leaked blocks and a clean
    allocator audit after the post-restart drain.
    """
    import shutil
    import tempfile

    from repro.serving.durability import Durability, recover

    bs = 16
    # stagger generation lengths by whole quanta: uniform lengths make
    # every admission wave finish in lockstep, so flush-boundary
    # checkpoints land exactly between waves with ZERO live rows and the
    # crash exercises only the trivial queued-requests path — mixed
    # lengths keep the pool continuously occupied mid-run, so the
    # pre-crash checkpoint always holds live snapshots to resume
    mn_max = max_new + 2 * quantum
    blocks_row = -(-(prompt_len + mn_max) // bs)
    scfg = ServingConfig(slots=prompt_len + mn_max + bs,
                         max_batch=max_batch, block_size=bs,
                         pool_blocks=(max_batch + 1) * blocks_row,
                         paged_kv=True, prefix_cache=False,
                         priority_classes=2)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    rng = np.random.default_rng(seed)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, prompt_len)
                    .astype(np.int32),
                    max_new=max_new + quantum * (i % 3), priority=i % 2)
            for i in range(n_req)]
    total_tokens = sum(r.max_new for r in reqs)

    # warm every executable either path dispatches: cold waves at each
    # pow2 row count, then one untimed mini crash/recover cycle (restore
    # executable + checkpoint capture/save + journal replay)
    wrng = np.random.default_rng(2**31 - 13)
    w = 1
    while w <= max_batch:
        warm = ContinuousScheduler(srv, quantum=quantum, record_events=False)
        for _ in range(w):
            warm.submit(Request(tokens=wrng.integers(0, cfg.vocab, prompt_len)
                                .astype(np.int32), max_new=2))
        warm.run()
        w *= 2
    wdir = tempfile.mkdtemp(prefix="bench_crash_warm_")
    try:
        warm = ContinuousScheduler(srv, quantum=quantum, record_events=False)
        Durability(warm, wdir, checkpoint_every=1)
        for _ in range(2):
            warm.submit(Request(tokens=wrng.integers(0, cfg.vocab, prompt_len)
                                .astype(np.int32), max_new=4))
        warm.step()
        wrec = recover(srv, wdir, quantum=quantum, record_events=False,
                       paranoid=PARANOID)
        wrec.run()
        wrec.check()
    finally:
        shutil.rmtree(wdir, ignore_errors=True)

    def clean_run():
        sched = ContinuousScheduler(srv, quantum=quantum,
                                    record_events=False)
        for r in reqs:
            sched.submit(r)
        t0 = time.perf_counter()
        sched.run()
        return sched, time.perf_counter() - t0

    tw, best = clean_run()
    tw2, wall2 = clean_run()
    best = min(best, wall2)
    twin = [tw.results[i] for i in range(n_req)]
    clean_tok_s = total_tokens / best
    crash_round = max(1, tw._round // 2)
    if checkpoint_every > 1 and crash_round % checkpoint_every == 0:
        # don't crash exactly on a checkpoint cut: land the kill between
        # cuts so recovery has live snapshots and/or a journal suffix to
        # replay (the interesting path, and what the smoke asserts check)
        crash_round += 1

    jdir = tempfile.mkdtemp(prefix="bench_crash_")
    try:
        s1 = ContinuousScheduler(srv, quantum=quantum)
        dur = Durability(s1, jdir, checkpoint_every=checkpoint_every)
        for r in reqs:
            s1.submit(r)
        t0 = time.perf_counter()
        for _ in range(crash_round):
            s1.step()
        t_pre = time.perf_counter() - t0
        ckpts = dur.checkpoints_written
        journal_bytes = os.path.getsize(os.path.join(jdir, "journal.jsonl"))
        # CRASH: the abandoned scheduler owns every donated buffer and
        # every host-side table — dropping it IS process death as far as
        # serving state goes; only the journal_dir survives
        t0 = time.perf_counter()
        s2 = recover(srv, jdir, checkpoint_every=checkpoint_every,
                     quantum=quantum, paranoid=PARANOID)
        t_rec = time.perf_counter() - t0
        info_rec = s2.recover_info
        t0 = time.perf_counter()
        while s2.step():
            pass
        t_post = time.perf_counter() - t0
        s2.check()
        stats = s2.paged_stats()
    finally:
        shutil.rmtree(jdir, ignore_errors=True)

    identical = all(
        [int(x) for x in s2.results[i]["tokens"]]
        == [int(x) for x in twin[i]["tokens"]] for i in range(n_req))
    goodput = total_tokens / (t_pre + t_rec + t_post)
    retention = goodput / clean_tok_s

    if smoke_asserts:
        assert identical, "post-restart streams diverge from the twin"
        assert all(s2.results[i]["status"].value == "completed"
                   for i in range(n_req))
        assert info_rec["resumed_rows"] + info_rec["chunk_rows"] >= 1 \
            or info_rec["replayed"] >= 1, info_rec
        assert not info_rec["refilled"], \
            f"unexpected corruption fallback: {info_rec['refilled']}"
        assert ckpts >= 1, "no checkpoint committed before the crash"
        assert stats["used_blocks"] == 0, \
            f"leaked {stats['used_blocks']} pool blocks after restart"

    tag = f"b{max_batch}_n{n_req}x{max_new}"
    rows = [(f"serve_crash_{tag}", t_rec * 1e6,
             f"goodput_through_restart_tok_s={goodput:.0f};"
             f"uninterrupted_tok_s={clean_tok_s:.0f};"
             f"goodput_retention={retention:.2f};"
             f"recovery_ms={t_rec * 1e3:.1f};"
             f"resumed_rows={info_rec['resumed_rows']};"
             f"replayed={info_rec['replayed']};"
             f"identical={identical}")]
    info = {"goodput_through_restart_tok_s": goodput,
            "uninterrupted_tok_s": clean_tok_s,
            "goodput_retention": retention,
            "recovery_ms": t_rec * 1e3,
            "phase_wall_s": {"pre_crash": t_pre, "recovery": t_rec,
                             "post_crash": t_post},
            "crash_round": crash_round,
            "checkpoints_before_crash": ckpts,
            "journal_bytes_at_crash": journal_bytes,
            "recover_info": {k: v for k, v in info_rec.items()
                             if k != "corrupt_keys"},
            "token_identical": identical,
            "pool": {"used_blocks": stats["used_blocks"],
                     "peak_used_blocks": stats["peak_used_blocks"],
                     "allocator_clean": True}}
    return rows, info


# ---------------------------------------------------------------------------
# speculative decoding: predictable-continuation Poisson trace (BENCH_8)
# ---------------------------------------------------------------------------

def _sim_accept(stream, hist_len: int = 32, k: int = 4,
                depth: int = 3) -> float:
    """Host replica of the longest-suffix n-gram drafter: mean delivered
    tokens per draft/verify window when speculating over ``stream``
    offline (no model involved — pure trace arithmetic). Used to *select*
    bench prompts: speculation's win is inherently workload-dependent, so
    the trace builder keeps prompts whose continuations the drafter can
    actually predict, and the acceptance number is reported alongside the
    speedup rather than hidden inside it."""
    hist = [-1] * (hist_len - 1) + [int(stream[0])]
    i, windows, delivered = 1, 0, 0
    while i < len(stream):
        h = hist[-hist_len:]
        best, bj = 0, -1
        for j in range(hist_len - 1):
            if h[j] < 0:
                continue
            s, run = 0, True
            for d in range(depth):
                if j - d < 0 or h[j - d] != h[hist_len - 1 - d] \
                        or h[hist_len - 1 - d] < 0:
                    run = False
                if run:
                    s += 1 << d
            if s >= best:
                best, bj = s, j
        if best > 0:
            period = hist_len - 1 - bj
            prop = [h[bj + 1 + (t % period)] for t in range(k)]
            prop = [p if p >= 0 else h[-1] for p in prop]
        else:
            prop = [h[-1]] * k
        acc = 0
        for t in range(k):
            if i + t < len(stream) and prop[t] == int(stream[i + t]):
                acc += 1
            else:
                break
        m = min(acc + 1, len(stream) - i)
        windows += 1
        delivered += m
        hist.extend(int(x) for x in stream[i:i + m])
        i += m
    return delivered / max(windows, 1)


def _predictable_trace(srv, cfg, n_req: int, max_new: int, seed: int,
                       draft_k: int, accept_floor: float = 3.5,
                       tail_len: int = 12, max_rounds: int = 8):
    """Build a predictable-continuation trace: seed short greedy streams
    from random prompts, re-prompt with each stream's *tail* (the model is
    already inside its attractor, so the continuation tends to stay
    periodic), and keep candidates whose offline drafter acceptance
    clears ``accept_floor``. Returns ``(requests, solo_streams)`` — the
    solo streams double as the token-identity oracle, so selection costs
    nothing extra. Falls back to the top-scoring candidates if fewer than
    ``n_req`` clear the floor."""
    rng = np.random.default_rng(seed)
    b = 8                                    # selection batch, fixed shape
    scored = []
    for _ in range(max_rounds):
        toks = rng.integers(0, cfg.vocab, (b, 8)).astype(np.int32)
        seeds = srv.generate(toks, 32)["tokens"]
        tails = np.asarray([s[-tail_len:] for s in seeds], np.int32)
        streams = srv.generate(tails, max_new)["tokens"]
        for r in range(b):
            m = _sim_accept(streams[r], k=draft_k)
            scored.append((m, [int(t) for t in tails[r]],
                           [int(t) for t in streams[r]]))
        if sum(1 for m, _, _ in scored if m >= accept_floor) >= n_req:
            break
    scored.sort(key=lambda c: -c[0])
    picked = [c for c in scored if c[0] >= accept_floor][:n_req]
    if len(picked) < n_req:                 # top-up, keep the trace sized
        picked = scored[:n_req]
    reqs = [Request(tokens=np.asarray(p, np.int32), max_new=max_new)
            for _, p, _ in picked]
    return reqs, [s for _, _, s in picked], \
        float(np.mean([m for m, _, _ in picked]))


def bench_speculative(cfg, params, eng, *, n_req: int = 12,
                      max_new: int = 96, max_batch: int = 8,
                      quantum: int = 16, draft_k: int = 4,
                      util: float = 0.9, seed: int = 0,
                      min_speedup: float = 1.5,
                      smoke_asserts: bool = True) -> tuple[list, dict]:
    """Speculative vs greedy decode on a predictable-continuation Poisson
    trace: same requests, same fixed profile, same paged pool — the spec
    scheduler must be token-identical to the greedy scheduler AND to the
    solo-generate oracle while delivering ``min_speedup`` more decode
    tokens/sec closed-loop. Reports measured acceptance (delivered tokens
    per verify window) next to the speedup; leaks are asserted zero on
    both pools."""
    tail_len = 12
    base = dict(slots=tail_len + max_new + 8, max_batch=max_batch,
                kv_bits=16, block_size=16)
    srv_g = AdaptiveServer(cfg, params, eng, ServingConfig(**base))
    srv_s = AdaptiveServer(cfg, params, eng,
                           ServingConfig(**base, speculate=True,
                                         draft_k=draft_k))
    reqs, solos, sel_accept = _predictable_trace(
        srv_g, cfg, n_req, max_new, seed, draft_k)
    total_tokens = sum(r.max_new for r in reqs)

    # measured acceptance: count (window, delivered) off the spec
    # segment's returned per-window counts
    acc = {"windows": 0, "delivered": 0}
    inner = srv_s._segment

    def counted(*a, **kw):
        out = inner(*a, **kw)
        ms = np.asarray(out[1])
        acc["windows"] += int((ms > 0).sum())
        acc["delivered"] += int(ms.sum())
        return out
    counted._cache_size = getattr(inner, "_cache_size", None)
    srv_s._segment = counted

    # warm admission-wave executables for every pow2 wave size the
    # open-loop run can hit (spec retirement is data-dependent, so waves
    # of any size occur), then closed-loop warm + timed capacity runs
    def _closed(srv):
        toks_by_rid = None
        for it in range(2):
            if it == 0:
                w = 1
                while w <= max_batch:
                    ws = ContinuousScheduler(srv, quantum=quantum,
                                             record_events=False)
                    for _ in range(w):
                        ws.submit(Request(tokens=np.ones(tail_len, np.int32),
                                          max_new=2))
                    ws.run()
                    w *= 2
            sched = ContinuousScheduler(srv, quantum=quantum,
                                        record_events=False)
            for r in reqs:
                sched.submit(Request(tokens=r.tokens, max_new=r.max_new))
            t0 = time.perf_counter()
            sched.run()
            cap = total_tokens / (time.perf_counter() - t0)
            toks_by_rid = [sched.results[i]["tokens"]
                           for i in range(len(reqs))]
            stats = sched.paged_stats() if sched.paged else None
        return cap, toks_by_rid, stats

    cap_g, toks_g, stats_g = _closed(srv_g)
    acc.update(windows=0, delivered=0)
    cap_s, toks_s, stats_s = _closed(srv_s)
    speedup = cap_s / cap_g
    accept = acc["delivered"] / max(acc["windows"], 1)

    # token identity: spec == greedy == solo oracle, per request
    identical = toks_s == toks_g and all(
        toks_s[i] == solos[i] for i in range(len(reqs)))

    # open-loop Poisson at `util` of the *greedy* capacity — spec rides
    # the same arrival process, so latency numbers compare like-for-like
    lam = util * cap_g / (total_tokens / len(reqs))
    arrivals = np.cumsum(np.random.default_rng(seed + 1)
                         .exponential(1.0 / lam, len(reqs)))
    g_t, g_mk = _run_continuous(srv_g, reqs, arrivals, quantum)
    s_t, s_mk = _run_continuous(srv_s, reqs, arrivals, quantum)
    g50, g99 = _percentiles((g_t - arrivals) * 1e3)
    s50, s99 = _percentiles((s_t - arrivals) * 1e3)

    leaked = ((stats_g or {}).get("used_blocks", 0)
              + (stats_s or {}).get("used_blocks", 0))
    if smoke_asserts:
        assert identical, \
            "speculative trace diverges from greedy/solo tokens"
        assert leaked == 0, f"leaked {leaked} pool blocks"
        assert speedup >= min_speedup, \
            f"spec closed-loop speedup {speedup:.2f}x < " \
            f"{min_speedup:.2f}x floor (accept={accept:.2f}/{draft_k + 1})"

    tag = f"b{max_batch}_q{quantum}_k{draft_k}_n{len(reqs)}x{max_new}"
    rows = [
        (f"serve_spec_{tag}", s_mk * 1e6,
         f"tok_s={cap_s:.0f};accept={accept:.2f}of{draft_k + 1};"
         f"speedup_vs_greedy={speedup:.2f}x;p50_ms={s50:.1f};"
         f"p99_ms={s99:.1f}"),
        (f"serve_greedy_{tag}", g_mk * 1e6,
         f"tok_s={cap_g:.0f};p50_ms={g50:.1f};p99_ms={g99:.1f}"),
    ]
    info = {"speedup_closed_loop": speedup,
            "spec_tok_s": cap_s, "greedy_tok_s": cap_g,
            "accept_mean_delivered_per_window": accept,
            "accept_offline_selected": sel_accept,
            "window": draft_k + 1, "draft_k": draft_k,
            "quantum": quantum, "n_req": len(reqs), "max_new": max_new,
            "token_identical": identical,
            "open_loop": {"spec_makespan_s": s_mk,
                          "greedy_makespan_s": g_mk,
                          "spec_p50_ms": s50, "spec_p99_ms": s99,
                          "greedy_p50_ms": g50, "greedy_p99_ms": g99},
            "pool": {"leaked_blocks": leaked}}
    return rows, info


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        description="Serving benchmarks: fused decode, continuous batching, "
                    "and paged-KV/shared-prefix serving. Emits "
                    "'name,us_per_call,derived' CSV rows (harness contract); "
                    "--json additionally writes structured results including "
                    "block-pool occupancy.")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="fused-vs-stepwise on the two acceptance points "
                           "only, then the Poisson + paged experiments")
    mode.add_argument("--smoke", action="store_true",
                      help="CI gate: tiny continuous-batching run plus a "
                           "paged shared-prefix point, seconds-scale; "
                           "asserts the paged KV-memory saving")
    ap.add_argument("--iters", type=int, default=3, metavar="N",
                    help="timed iterations per fused/stepwise point after "
                         "one untimed compile warmup (default: 3)")
    ap.add_argument("--util", type=float, default=0.95, metavar="U",
                    help="offered Poisson load as a fraction in (0, 1] of "
                         "the measured closed-loop capacity (default: 0.95)")
    ap.add_argument("--n-req", type=int, default=48, metavar="N",
                    help="requests in each open-loop trace (default: 48)")
    ap.add_argument("--seed", type=int, default=0, metavar="S",
                    help="base RNG seed: prompt contents use S, arrival "
                         "times S+1 — traces are fully reproducible "
                         "(default: 0)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write results as JSON: every CSV row plus "
                         "paged block-pool occupancy and registry stats")
    ap.add_argument("--paranoid", action="store_true",
                    help="run the BlockAllocator.check() refcount audit "
                         "every scheduler step in every bench (the chaos "
                         "bench always audits; the priority bench's "
                         "measured p99-ratio traces stay audit-free so the "
                         "assertion compares like with like)")
    args = ap.parse_args(argv)
    if not 0.0 < args.util <= 1.0:
        ap.error(f"--util must be in (0, 1], got {args.util}")
    if args.iters < 1:
        ap.error(f"--iters must be >= 1, got {args.iters}")
    if args.n_req < 1:
        ap.error(f"--n-req must be >= 1, got {args.n_req}")
    return args


def _assert_occupancy_consistent(stats: dict) -> None:
    """Occupancy must be refcount-accurate and three-way: blocks with a
    live reference (``live_blocks``, from the refcounts), retired blocks a
    registered prefix still caches in the allocator LRU
    (``lru_cached_blocks`` — allocatable capacity AND resurrectable
    content), and free blocks must exactly partition the pool — the
    cross-check between the refcount, LRU, and free-list bookkeeping that
    the bench's saving numbers stand on."""
    if not stats.get("paged"):
        return
    assert stats["used_blocks"] == stats["live_blocks"], stats
    assert stats["live_blocks"] + stats["lru_cached_blocks"] \
        + stats["free_blocks"] == stats["pool_blocks"], stats


def main(argv=None) -> None:
    args = _parse_args(argv)
    global PARANOID
    PARANOID = bool(getattr(args, "paranoid", False))
    cfg, params, eng = _build()
    paged_info = chunk_info = prio_info = chaos_info = spec_info = None
    crash_info = None
    if args.smoke:
        rows = bench_poisson(cfg, params, eng, n_req=8, util=args.util,
                             max_batch=4, quantum=4, seed=args.seed,
                             lens=(8,), news=(4, 8, 16))
        # 16 requests so most of each capacity run is steady-state shared
        # admissions (every run starts a fresh scheduler whose first wave
        # is cold by construction)
        prows, paged_info = bench_shared_prefix(
            cfg, params, eng, n_req=16, sys_len=64, tail_len=8, max_new=4,
            max_batch=4, quantum=4, util=args.util, seed=args.seed)
        rows += prows
        _assert_occupancy_consistent(paged_info["paged"])
        assert paged_info["kv_saving_frac"] >= 0.30, \
            f"paged KV footprint saving {paged_info['kv_saving_frac']:.0%} " \
            f"< 30% acceptance floor"
        # small chunked-prefill point: exercises the chunk planner +
        # continuation waves end-to-end (seconds-scale); the tuned
        # long-prompt tail-latency comparison runs in the full bench and
        # is recorded in BENCH_4.json
        crows, chunk_info = bench_chunked_prefill(
            cfg, params, eng, n_req=8, long_len=96, long_every=4, chunk=32,
            max_batch=4, quantum=4, util=args.util, seed=args.seed)
        rows += crows
        # mixed-class preemption point: saver hogs + periodic critical
        # arrivals on a 2-row pool. Asserts critical p99 beats the FIFO
        # baseline and the ledger replays exactly against the stepwise
        # oracle (with ≥1 preemption provably in the event log); the tuned
        # ≥2× contention number runs in the full bench → BENCH_5.json
        prows2, prio_info = bench_priority(
            cfg, params, eng, n_saver=8, n_crit=3, saver_new=24,
            max_batch=2, quantum=4, seed=args.seed, min_speedup=1.2)
        rows += prows2
        assert prio_info["preemptions"] >= 1, prio_info
        # chaos point: Poisson trace + seeded NaN-logit faults + an
        # allocator-drought round + a flush stall + client cancellations.
        # Asserts zero leaked pool blocks (paranoid per-step audit + final
        # check), >=1 precision-fallback recovery, and that the recovered
        # request's tokens match a clean accuracy-critical run — the tuned
        # goodput/recovery numbers run in the full bench -> BENCH_6.json
        chrows, chaos_info = bench_chaos(
            cfg, params, eng, n_req=10, max_new=8, max_batch=4, quantum=4,
            util=args.util, cancel_frac=0.2, seed=args.seed,
            smoke_asserts=True)
        rows += chrows
        assert chaos_info["recovered"] >= 1, chaos_info
        # crash-restart point: journal + checkpoint, kill at a mid-run
        # flush boundary, recover into a fresh scheduler. Asserts
        # token-identity of every stream vs the uninterrupted twin, a
        # committed pre-crash checkpoint, zero leaked blocks; the tuned
        # goodput-through-restart + recovery-latency numbers run in the
        # full bench -> BENCH_9.json
        krows, crash_info = bench_crash(
            cfg, params, eng, n_req=8, max_new=12, max_batch=4, quantum=4,
            checkpoint_every=2, seed=args.seed, smoke_asserts=True)
        rows += krows
        assert crash_info["token_identical"], crash_info
        # speculative point: draft/verify windows on a selected
        # predictable-continuation trace — asserts token identity against
        # both the greedy scheduler and the solo-generate oracle, zero
        # leaked blocks on both pools, and >=1.2x closed-loop decode
        # throughput; the tuned >=1.5x point runs in the full bench ->
        # BENCH_8.json
        srows, spec_info = bench_speculative(
            cfg, params, eng, n_req=6, max_new=64, max_batch=4, quantum=16,
            util=args.util, seed=args.seed, min_speedup=1.2,
            smoke_asserts=True)
        rows += srows
    else:
        rows = run(QUICK_POINTS if args.quick else POINTS, iters=args.iters)
        rows += bench_poisson(cfg, params, eng, n_req=args.n_req,
                              util=args.util, seed=args.seed)
        prows, paged_info = bench_shared_prefix(cfg, params, eng,
                                                n_req=max(2, args.n_req // 2),
                                                util=args.util,
                                                seed=args.seed)
        rows += prows
        _assert_occupancy_consistent(paged_info["paged"])
        # the tail-latency effect needs headroom: queueing delay at 0.95
        # util would swamp the admission-stall difference being measured
        crows, chunk_info = bench_chunked_prefill(
            cfg, params, eng, util=min(args.util, 0.7), seed=args.seed)
        rows += crows
        # contention point for the acceptance number: critical-class p99
        # must improve ≥2× over FIFO while saver throughput degrades
        # gracefully (the ratio is recorded in the JSON)
        prows2, prio_info = bench_priority(
            cfg, params, eng, seed=args.seed, min_speedup=2.0)
        rows += prows2
        # chaos at scale: random seeded injections (p_nan) on top of the
        # deterministic targets; goodput + completion-rate-by-status +
        # recovery latency land in the JSON for BENCH_6
        chrows, chaos_info = bench_chaos(
            cfg, params, eng, n_req=max(8, args.n_req // 2),
            util=min(args.util, 0.8), p_nan=0.05, seed=args.seed,
            smoke_asserts=True)
        rows += chrows
        # crash-restart at scale: goodput through the kill+recover cycle
        # and recovery latency land in the JSON for BENCH_9
        krows, crash_info = bench_crash(
            cfg, params, eng, n_req=max(8, args.n_req // 3), max_new=12,
            max_batch=4, quantum=4, checkpoint_every=2, seed=args.seed,
            smoke_asserts=True)
        rows += krows
        # speculative decoding at scale: the >=1.5x acceptance number,
        # measured acceptance, and open-loop latency land in the JSON for
        # BENCH_8
        srows, spec_info = bench_speculative(
            cfg, params, eng, n_req=12, max_new=96, max_batch=8,
            quantum=16, util=min(args.util, 0.9), seed=args.seed,
            min_speedup=1.5, smoke_asserts=True)
        rows += srows
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.json:
        payload = {
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
            "config": {"util": args.util, "n_req": args.n_req,
                       "seed": args.seed, "iters": args.iters},
        }
        if paged_info is not None:
            payload["paged"] = paged_info
        if chunk_info is not None:
            payload["chunked_prefill"] = chunk_info
        if prio_info is not None:
            payload["priority_preemption"] = prio_info
        if chaos_info is not None:
            payload["chaos"] = chaos_info
        if crash_info is not None:
            payload["crash"] = crash_info
        if spec_info is not None:
            payload["speculative"] = spec_info
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=int)
        print(f"# json written to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Serving decode-loop benchmark: fused scan generate vs seed per-token loop.

Emits ``name,us_per_call,derived`` rows (harness contract). Each point runs
the same greedy generation twice — ``serve_fused_*`` (single jitted
``lax.scan`` dispatch, donated caches) and ``serve_stepwise_*`` (the seed
loop: one dispatch + ``np.asarray`` host sync + host argmax per token) — and
reports tokens/sec plus the fused/stepwise speedup in ``derived``.

CPU interpret-path numbers: what they measure is the *runtime overhead around
the kernels* (dispatch count, host syncs, cache copies), which is exactly the
adaptive-inference tax the paper says must be negligible. TPU numbers come
from deployment.

  PYTHONPATH=src python benchmarks/serving_bench.py [--quick] [--iters N]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, ServingConfig

# (batch, prompt_len, max_new, kv_bits) — batch ≥ 4 / new ≥ 32 are the
# acceptance points for the fused-loop speedup
POINTS = [
    (1, 16, 32, 16),
    (4, 16, 32, 16),
    (4, 16, 32, 8),
    (4, 64, 64, 16),
    (8, 32, 64, 16),
    (8, 32, 64, 8),
]
QUICK_POINTS = [(4, 16, 32, 16), (4, 16, 32, 8)]


def _build(arch: str = "granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


def _time(fn, iters: int) -> float:
    fn()                                  # warmup: compile outside the clock
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def bench_point(cfg, params, eng, b, s, new, kv_bits, iters: int):
    scfg = ServingConfig(slots=s + new + 8, kv_bits=kv_bits, max_batch=b)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (b, s)).astype(np.int32)

    t_fused = _time(lambda: srv.generate(prompts, new), iters)
    t_step = _time(lambda: srv.generate_stepwise(prompts, new), iters)

    tag = f"b{b}_p{s}_n{new}_kv{kv_bits}"
    toks = b * new
    tok_s_fused = toks / t_fused
    tok_s_step = toks / t_step
    speedup = t_step / t_fused
    rows = [
        (f"serve_fused_{tag}", t_fused * 1e6,
         f"tok_s={tok_s_fused:.0f};speedup_vs_stepwise={speedup:.2f}x"),
        (f"serve_stepwise_{tag}", t_step * 1e6,
         f"tok_s={tok_s_step:.0f};dispatches_per_call={new}"),
    ]
    return rows, speedup


def run(points=None, iters: int = 3) -> list[tuple]:
    cfg, params, eng = _build()
    rows: list[tuple] = []
    for b, s, new, kv in (points or POINTS):
        point_rows, _ = bench_point(cfg, params, eng, b, s, new, kv, iters)
        rows.extend(point_rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two acceptance points only")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    rows = run(QUICK_POINTS if args.quick else POINTS, iters=args.iters)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

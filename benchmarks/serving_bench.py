"""Serving benchmarks: fused decode loop + continuous-batching scheduler.

Emits ``name,us_per_call,derived`` rows (harness contract). Two experiments:

* **fused vs stepwise** (``serve_fused_*`` / ``serve_stepwise_*``): the same
  greedy generation through the single-dispatch ``lax.scan`` path vs the seed
  per-token host loop — the PR-1 decode-fusion win.
* **continuous vs static** (``serve_continuous_*`` / ``serve_static_*``): an
  open-loop Poisson-arrival workload (heterogeneous prompt lengths and
  ``max_new``) served by the :class:`ContinuousScheduler` slot pool vs static
  grouped ``serve()`` (a group must finish before the next starts). Arrival
  rate is calibrated to ``--util`` of the continuous path's measured
  closed-loop capacity; rows report tokens/sec over the makespan and
  p50/p99 request latency (arrival → completion). The static path burns
  decode steps as dead padding whenever a group mixes ``max_new`` budgets —
  the continuous pool refills those rows instead, which is where the
  throughput gap comes from.

CPU interpret-path numbers: what they measure is the *runtime overhead around
the kernels* (dispatch count, host syncs, cache copies, dead-step density),
which is exactly the adaptive-inference tax the paper says must be
negligible. TPU numbers come from deployment.

  PYTHONPATH=src python benchmarks/serving_bench.py [--quick|--smoke]
                                                    [--iters N] [--util U]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig
from repro.serving.scheduler import ContinuousScheduler

# (batch, prompt_len, max_new, kv_bits) — batch ≥ 4 / new ≥ 32 are the
# acceptance points for the fused-loop speedup
POINTS = [
    (1, 16, 32, 16),
    (4, 16, 32, 16),
    (4, 16, 32, 8),
    (4, 64, 64, 16),
    (8, 32, 64, 16),
    (8, 32, 64, 8),
]
QUICK_POINTS = [(4, 16, 32, 16), (4, 16, 32, 8)]


def _build(arch: str = "granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


def _time(fn, iters: int) -> float:
    fn()                                  # warmup: compile outside the clock
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def bench_point(cfg, params, eng, b, s, new, kv_bits, iters: int):
    scfg = ServingConfig(slots=s + new + 8, kv_bits=kv_bits, max_batch=b)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (b, s)).astype(np.int32)

    t_fused = _time(lambda: srv.generate(prompts, new), iters)
    t_step = _time(lambda: srv.generate_stepwise(prompts, new), iters)

    tag = f"b{b}_p{s}_n{new}_kv{kv_bits}"
    toks = b * new
    tok_s_fused = toks / t_fused
    tok_s_step = toks / t_step
    speedup = t_step / t_fused
    rows = [
        (f"serve_fused_{tag}", t_fused * 1e6,
         f"tok_s={tok_s_fused:.0f};speedup_vs_stepwise={speedup:.2f}x"),
        (f"serve_stepwise_{tag}", t_step * 1e6,
         f"tok_s={tok_s_step:.0f};dispatches_per_call={new}"),
    ]
    return rows, speedup


def run(points=None, iters: int = 3) -> list[tuple]:
    cfg, params, eng = _build()
    rows: list[tuple] = []
    for b, s, new, kv in (points or POINTS):
        point_rows, _ = bench_point(cfg, params, eng, b, s, new, kv, iters)
        rows.extend(point_rows)
    return rows


# ---------------------------------------------------------------------------
# continuous batching: open-loop Poisson workload
# ---------------------------------------------------------------------------

# discrete length/budget menus keep the static path's executable count small
# (group maxlen / max(max_new) are drawn from these sets), so the timed runs
# measure serving, not compilation. The long-tailed max_new menu is the
# canonical continuous-batching traffic shape: most requests are short, a few
# run long — a static group burns max(max_new) steps for every row.
PROMPT_LENS = (8, 16)
MAX_NEWS = (4, 8, 16, 128)


def _workload(cfg, n_req: int, seed: int,
              lens=PROMPT_LENS, news=MAX_NEWS) -> list[Request]:
    """Round-robin over the length/budget menus (prompt contents seeded):
    composition is deterministic — a reproducible trace — so run-to-run
    variance comes from arrival times and the machine, not from which
    requests happened to land in the same static group."""
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, cfg.vocab,
                                        lens[i % len(lens)]).astype(np.int32),
                    max_new=news[i % len(news)])
            for i in range(n_req)]


def _percentiles(lat: np.ndarray) -> tuple[float, float]:
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def _run_continuous(srv, reqs, arrivals, quantum):
    n = len(reqs)
    sched = ContinuousScheduler(srv, quantum=quantum, record_events=False)
    done_t = np.zeros((n,))
    n_done, nxt = 0, 0
    t0 = time.perf_counter()
    while n_done < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            sched.submit(reqs[nxt])
            nxt += 1
        busy = sched.step()                # admit + segment + flush
        if not busy and nxt < n:           # idle until the next arrival
            time.sleep(min(1e-3, max(0.0, arrivals[nxt] - now)))
        for rid, _res in sched.poll_completed():
            done_t[rid] = time.perf_counter() - t0
            n_done += 1
    return done_t, time.perf_counter() - t0


def _run_static(srv, reqs, arrivals, max_batch):
    n = len(reqs)
    done_t = np.zeros((n,))
    n_done, nxt = 0, 0
    backlog: list[int] = []
    t0 = time.perf_counter()
    while n_done < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            backlog.append(nxt)
            nxt += 1
        if backlog:                        # serve the oldest arrivals as one
            group, backlog = backlog[:max_batch], backlog[max_batch:]
            srv.serve([reqs[i] for i in group])
            t_done = time.perf_counter() - t0
            for i in group:
                done_t[i] = t_done
            n_done += len(group)
        elif nxt < n:
            time.sleep(min(1e-3, max(0.0, arrivals[nxt] - now)))
    return done_t, time.perf_counter() - t0


def bench_poisson(cfg, params, eng, *, n_req: int = 48, util: float = 0.95,
                  max_batch: int = 8, quantum: int = 8, seed: int = 0,
                  lens=PROMPT_LENS, news=MAX_NEWS) -> list[tuple]:
    scfg = ServingConfig(slots=max(lens) + max(news) + 8, max_batch=max_batch)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    reqs = _workload(cfg, n_req, seed, lens, news)
    total_tokens = sum(r.max_new for r in reqs)

    # warm every admission-wave executable the open-loop run can hit: wave
    # row-counts bucket to powers of two and prompts to pow2 length buckets,
    # so cover (1,2,4,...,max_batch) × lens with throwaway 2-token requests
    w = 1
    while w <= max_batch:
        for length in lens:
            warm = ContinuousScheduler(srv, quantum=quantum)
            for _ in range(w):
                warm.submit(Request(tokens=np.ones(length, np.int32),
                                    max_new=2))
            warm.run()
        w *= 2
    # closed-loop warm pass, then a second run measures the continuous
    # capacity that sets the arrival rate — calibration excludes compile time
    for _ in range(2):
        sched = ContinuousScheduler(srv, quantum=quantum)
        for r in reqs:
            sched.submit(r)
        t0 = time.perf_counter()
        sched.run()
        cap_tok_s = total_tokens / (time.perf_counter() - t0)
    # warm every static executable the open-loop run can hit
    for length in lens:
        for mn in news:
            srv.serve([Request(tokens=np.ones(length, np.int32), max_new=mn)
                       for _ in range(max_batch)])

    lam = util * cap_tok_s / (total_tokens / n_req)     # requests / second
    arrivals = np.cumsum(np.random.default_rng(seed + 1)
                         .exponential(1.0 / lam, n_req))

    cont_t, cont_mk = _run_continuous(srv, reqs, arrivals, quantum)
    stat_t, stat_mk = _run_static(srv, reqs, arrivals, max_batch)

    c50, c99 = _percentiles((cont_t - arrivals) * 1e3)
    s50, s99 = _percentiles((stat_t - arrivals) * 1e3)
    speedup = stat_mk / cont_mk
    tag = f"b{max_batch}_q{quantum}_n{n_req}_u{util:g}"
    return [
        (f"serve_continuous_{tag}", cont_mk * 1e6,
         f"tok_s={total_tokens / cont_mk:.0f};p50_ms={c50:.1f};"
         f"p99_ms={c99:.1f};speedup_vs_static={speedup:.2f}x"),
        (f"serve_static_{tag}", stat_mk * 1e6,
         f"tok_s={total_tokens / stat_mk:.0f};p50_ms={s50:.1f};"
         f"p99_ms={s99:.1f};offered_tok_s={util * cap_tok_s:.0f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two acceptance points only")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: tiny continuous-batching run, seconds-scale")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--util", type=float, default=0.95,
                    help="offered load as a fraction of continuous capacity")
    ap.add_argument("--n-req", type=int, default=48)
    args = ap.parse_args()
    if args.smoke:
        cfg, params, eng = _build()
        rows = bench_poisson(cfg, params, eng, n_req=8, util=args.util,
                             max_batch=4, quantum=4,
                             lens=(8,), news=(4, 8, 16))
    else:
        rows = run(QUICK_POINTS if args.quick else POINTS, iters=args.iters)
        cfg, params, eng = _build()
        rows += bench_poisson(cfg, params, eng, n_req=args.n_req,
                              util=args.util)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

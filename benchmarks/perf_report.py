"""§Perf: render before/after comparisons for the hillclimbed cells from
dry-run artifacts (baseline vs tagged variants)."""
from __future__ import annotations

import json
import os

from repro.core.energy import TPU_V5E, roofline_terms

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def load(tag: str) -> dict | None:
    p = os.path.join(ART, "dryrun", tag + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def terms_of(rec: dict) -> dict:
    a = rec["analysis"]
    chips = rec["devices"]
    t = roofline_terms(a["flops"] * chips, a["bytes_accessed"] * chips,
                       a["collective_bytes"]["total"] * chips, chips, TPU_V5E)
    mem = rec["production"]["memory"]
    # structural lower bound on HBM traffic: weights/optimizer + step I/O
    lower = (mem["argument_bytes"] + mem["output_bytes"]) / TPU_V5E.hbm_bw
    t["memory_lower_s"] = lower
    t["t_step_lower_s"] = max(t["compute_s"], lower, t["collective_s"])
    t["fraction_upper"] = t["compute_s"] / t["t_step_s"]
    t["fraction_lower_bound_model"] = t["compute_s"] / t["t_step_lower_s"]
    return t


def compare(cell: str, variants: list[tuple[str, str]]) -> list[dict]:
    rows = []
    for label, tag in variants:
        rec = load(tag)
        if rec is None or rec.get("status") != "ok" or "analysis" not in rec:
            rows.append({"variant": label, "status": "missing"})
            continue
        t = terms_of(rec)
        a = rec["analysis"]
        rows.append({
            "variant": label,
            "flops_dev": a["flops"],
            "bytes_dev": a["bytes_accessed"],
            "coll_dev_gib": a["collective_bytes"]["total"] / 2**30,
            "compute_s": t["compute_s"],
            "memory_s": t["memory_s"],
            "memory_lower_s": t["memory_lower_s"],
            "collective_s": t["collective_s"],
            "t_step_s": t["t_step_s"],
            "t_step_lower_s": t["t_step_lower_s"],
            "frac_struct": t["fraction_lower_bound_model"],
            "args_gib": rec["production"]["memory"]["argument_bytes"] / 2**30,
        })
    return rows


CELLS = {
    "qwen1.5-110b × train_4k (most collective-bound)": [
        ("baseline", "qwen1.5-110b__train_4k__pod1"),
        ("+constraints", "qwen1.5-110b__train_4k__pod1__con"),
        ("+constraints+dots-remat", "qwen1.5-110b__train_4k__pod1__con-dots"),
        ("+constraints+bf16-reshard", "qwen1.5-110b__train_4k__pod1__con-bf16"),
    ],
    "hymba-1.5b × prefill_32k (worst useful-ratio)": [
        ("baseline (masked SWA)", "hymba-1.5b__prefill_32k__pod1"),
        ("+swa-block-skip", "hymba-1.5b__prefill_32k__pod1__swa"),
        ("+swa+constraints", "hymba-1.5b__prefill_32k__pod1__swa-con"),
    ],
    "qwen2-72b × decode_32k (paper-representative: quantized serving)": [
        ("baseline bf16 W/KV", "qwen2-72b__decode_32k__pod1"),
        ("W8 + KV8 (paper data-approx)", "qwen2-72b__decode_32k__pod1__w8__kv8"),
        ("W4 + KV8", "qwen2-72b__decode_32k__pod1__w4__kv8"),
        ("W8+KV8+constraints", "qwen2-72b__decode_32k__pod1__w8__kv8__con"),
        ("W8+KV8+con+serve-layout", "qwen2-72b__decode_32k__pod1__w8__kv8__srv"),
        ("W4+KV8+con+serve-layout", "qwen2-72b__decode_32k__pod1__w4__kv8__srv"),
        ("W8+KV4+con (int4 cache)", "qwen2-72b__decode_32k__pod1__w8__kv4__con"),
    ],
}


def main() -> None:
    for cell, variants in CELLS.items():
        print(f"\n## {cell}")
        rows = compare(cell, variants)
        hdr = ("| variant | FLOPs/dev | coll GiB/dev | compute_s | mem_s(ub) | "
               "mem_s(struct) | coll_s | t_step(struct) | frac(struct) |")
        print(hdr)
        print("|" + "---|" * 9)
        for r in rows:
            if r.get("status") == "missing":
                print(f"| {r['variant']} | (pending) |" + " |" * 7)
                continue
            print(f"| {r['variant']} | {r['flops_dev']:.2e} | "
                  f"{r['coll_dev_gib']:.1f} | {r['compute_s']:.2e} | "
                  f"{r['memory_s']:.2e} | {r['memory_lower_s']:.2e} | "
                  f"{r['collective_s']:.2e} | {r['t_step_lower_s']:.2e} | "
                  f"{r['frac_struct']:.3f} |")


if __name__ == "__main__":
    main()

"""Offline per-layer KV precision search → the ``--precision-policy`` file.

The offline half of the precision ladder (docs/serving.md §Precision
ladder): :meth:`ProfileManager.search_precision` walks the bytes/accuracy
frontier by greedily lowering one layer's KV bit-width one rung at a time
(16 → 8 → 4), scoring each candidate schedule by its logit deviation from
the all-bf16 baseline on a fixed probe batch and costing it by the analytic
KV bytes a decode step writes+reads per token.  The winning schedule is a
plain ``int32[n_layers]`` array — *data* to the serving engine's jitted
decode (the ``kv_table`` row), never a retrace.

  PYTHONPATH=src python benchmarks/precision_frontier.py \
      --arch granite-3-2b --max-drop 0.05 --json policy.json

The JSON payload feeds ``repro.launch.serve --precision-policy policy.json``
(profile 0 pins the all-high row; the rest ride the searched schedule).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T


def kv_bytes_per_token(cfg, sched) -> float:
    """Analytic KV bytes one decoded token writes (K+V, all layers).

    The structural cost the schedule controls: each layer stores
    ``2 * n_kv * head_dim`` values per token at its own bit-width — the
    quantity that sets pool capacity at a fixed block count.
    """
    return float(sum(2 * cfg.n_kv * cfg.head_dim * int(b) / 8 for b in sched))


def build_score_fn(cfg, params, bits_row, probe_tokens, slots: int = 32):
    """Proxy degradation: mean last-token logit deviation vs the all-16 row.

    Runs the *same* prefill executable with ``kv_sched`` as data, so every
    candidate schedule is one forward pass, and the all-high row scores an
    exact 0 (``kv_refine`` at eff>=16 is a passthrough).
    """
    batch = {"tokens": jnp.asarray(probe_tokens)}

    def logits_of(sched):
        y, _ = T.prefill(params, cfg, bits_row, batch, slots,
                         kv_sched=jnp.asarray(sched, jnp.int32))
        return np.asarray(y, np.float64)

    base = logits_of(np.full((cfg.n_layers,), 16, np.int32))
    denom = float(np.abs(base).mean()) + 1e-12

    def score(sched) -> float:
        return float(np.abs(logits_of(sched) - base).mean()) / denom

    return score


def search(arch: str, max_drop: float, full: bool = False,
           seed: int = 0) -> dict:
    cfg = get_config(arch) if full else get_smoke(arch)
    if not cfg.causal:
        raise SystemExit("encoder-only arch has no KV decode path")
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    bits_row = jnp.asarray(eng.table)[0]
    rng = np.random.default_rng(seed)
    probe = rng.integers(0, cfg.vocab, (4, 12)).astype(np.int32)
    score_fn = build_score_fn(cfg, params, bits_row, probe)
    # the search is a ProfileManager method (same object that binds profiles
    # online) but needs no energy ledger — a zero-budget manager is fine
    mgr = ProfileManager([ProfileStats("hi", 0.99, 1.0, 1.0)],
                         accuracy_target=0.985, accuracy_floor=0.95,
                         budget_j=0.0)
    sched, frontier = mgr.search_precision(
        cfg.n_layers, score_fn, lambda s: kv_bytes_per_token(cfg, s),
        ladder=(16, 8, 4), max_drop=max_drop)
    return {
        "arch": arch, "n_layers": cfg.n_layers, "max_drop": max_drop,
        "schedule": [int(b) for b in sched],
        "score": frontier[-1]["score"],
        "bytes_per_token": frontier[-1]["bytes"],
        "bytes_per_token_all16": frontier[0]["bytes"],
        "frontier": frontier,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Search a per-layer KV bit-width schedule (16/8/4) on "
                    "the bytes/accuracy frontier; --json writes the "
                    "--precision-policy payload for repro.launch.serve.")
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCHS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--max-drop", type=float, default=0.05,
                    help="proxy-score budget: max mean relative logit "
                         "deviation from the all-bf16 baseline (default "
                         "0.05)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="write the searched schedule + frontier as JSON")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = search(args.arch, args.max_drop, full=args.full, seed=args.seed)
    print(f"# {args.arch}: schedule={out['schedule']} "
          f"score={out['score']:.4f} "
          f"bytes/token {out['bytes_per_token_all16']:.0f} -> "
          f"{out['bytes_per_token']:.0f} "
          f"({out['bytes_per_token']/out['bytes_per_token_all16']:.2f}x)")
    for st in out["frontier"]:
        print(f"frontier,{st['bytes']:.0f},{st['score']:.5f},"
              f"{'/'.join(str(b) for b in st['schedule'])}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# json written to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Framework-wide optimized-vs-baseline roofline table (§Perf generalization).

Compares the baseline pod1 artifacts against the ``__con`` (train:
activation-sharding constraints + SWA skip) and ``__w8__kv8__con`` (decode:
int8 weights + int8 KV + constraints) variants for every arch.
"""
from __future__ import annotations

import json
import os

from repro.core.energy import TPU_V5E, roofline_terms

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def _load(tag):
    p = os.path.join(ART, "dryrun", tag + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        rec = json.load(f)
    return rec if rec.get("status") == "ok" and "analysis" in rec else None


def _tstep(rec) -> tuple[float, float]:
    a = rec["analysis"]
    chips = rec["devices"]
    t = roofline_terms(a["flops"] * chips, a["bytes_accessed"] * chips,
                       a["collective_bytes"]["total"] * chips, chips, TPU_V5E)
    mem = rec["production"]["memory"]
    lower = (mem["argument_bytes"] + mem["output_bytes"]) / TPU_V5E.hbm_bw
    t_low = max(t["compute_s"], lower, t["collective_s"])
    return t_low, t["compute_s"] / t_low if t_low else 0.0


def rows(shape: str, suffix: str) -> list[dict]:
    out = []
    from repro.configs import ARCHS
    for arch in ARCHS:
        base = _load(f"{arch}__{shape}__pod1")
        opt = _load(f"{arch}__{shape}__pod1{suffix}")
        if not base or not opt:
            continue
        tb, fb = _tstep(base)
        to, fo = _tstep(opt)
        out.append(dict(arch=arch, shape=shape,
                        t_base_s=tb, t_opt_s=to,
                        speedup=tb / to if to else 0.0,
                        frac_base=fb, frac_opt=fo))
    return out


def main() -> None:
    all_rows = (rows("train_4k", "__con")
                + rows("prefill_32k", "__w8__con")
                + rows("decode_32k", "__w8__kv8__con"))
    print("| arch | shape | t_step base | t_step opt | speedup | frac base→opt |")
    print("|" + "---|" * 6)
    for r in all_rows:
        print(f"| {r['arch']} | {r['shape']} | {r['t_base_s']:.3e} "
              f"| {r['t_opt_s']:.3e} | {r['speedup']:.2f}× "
              f"| {r['frac_base']:.3f} → {r['frac_opt']:.3f} |")
    with open(os.path.join(ART, "opt_table.json"), "w") as f:
        json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()

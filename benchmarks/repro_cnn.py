"""Paper reproduction benchmarks: Table 1, Fig. 3, Fig. 4 analogues.

Per-profile QAT (each profile trained separately from a shared init, exactly
like the paper's per-configuration engines), then:

* **Table 1** — accuracy / modeled latency / weight-image bytes (LUT+BRAM
  analogue) / modeled power per profile.
* **Fig. 3**  — the accuracy-vs-energy Pareto points (CSV).
* **Fig. 4**  — merged adaptive engine (A8-W8 + Mixed): resource overhead vs
  the largest standalone engine, plus the 10 Ah-budget battery simulation
  (classifications executable, adaptive vs non-adaptive).

Training on CPU is minutes per profile → results cache to
``artifacts/repro/table1.json``; delete the file to retrain.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import TPU_V5E, activity_factor, step_energy
from repro.core.manager import ProfileStats, battery_simulation
from repro.core.merge import merge_plan
from repro.core.profiles import paper_profiles, profile_table
from repro.data.digits import batches, make_dataset
from repro.models import cnn as C
from repro.optim.adam import AdamConfig, adam_init, adam_update

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")
CACHE = os.path.join(ART, "repro", "table1.json")

# paper's measured reference points (Table 1) for trend validation
PAPER_TABLE1 = {
    "A16-W8": {"acc": 98.9, "power_mw": 160},
    "A16-W4": {"acc": 95.3, "power_mw": 134},
    "A8-W8": {"acc": 98.8, "power_mw": 142},
    "A8-W4": {"acc": 95.3, "power_mw": 132},
    "A4-W4": {"acc": 95.8, "power_mw": 141},
}

# modeled per-inference time for the tiny CNN on one v5e core: the paper's
# latency is precision-INDEPENDENT (HLS schedule bound) — we mirror that by
# deriving one latency from the float roofline and holding it constant.
_CNN_MACS = 2 * (28 * 28 * 3 * 3 * 1 * 64 + 14 * 14 * 3 * 3 * 64 * 64
                 + 7 * 7 * 64 * 10)
CNN_LATENCY_S = max(_CNN_MACS / TPU_V5E.peak_flops, 2e-6)  # dispatch floor


def train_profile(profile_idx: int, table, steps: int = 120,
                  seed: int = 0) -> dict:
    cfg = C.CNNConfig()
    params = C.init_cnn(cfg, jax.random.PRNGKey(seed))
    acfg = AdamConfig(lr=1e-3, total_steps=steps, warmup_steps=10)
    tab = jnp.asarray(table)

    @jax.jit
    def step(params, opt, images, labels):
        br = tab[profile_idx]
        (l, m), g = jax.value_and_grad(C.cnn_loss, has_aux=True)(
            params, br, {"images": images, "labels": labels})
        params, opt, _ = adam_update(acfg, g, opt, params)
        return params, opt, l

    train_x, train_y = make_dataset(4096, seed=1, difficulty="hard")
    opt = adam_init(params)
    it = batches(train_x, train_y, 256, seed=3 + profile_idx)
    for _ in range(steps):
        x, y = next(it)
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y))
    return params


def profile_energy(name: str, a_bits: int, w_bits: int) -> tuple[float, float]:
    """(power_w, energy_j) per inference under the activity model."""
    mem_ratio = min(w_bits, 16) / 16.0
    act = activity_factor(min(a_bits, 16), min(w_bits, 16), mem_ratio)
    e = step_energy(CNN_LATENCY_S, act, chips=1)
    return e / CNN_LATENCY_S, e


def run_table1(force: bool = False, steps: int = 120) -> dict:
    if os.path.exists(CACHE) and not force:
        with open(CACHE) as f:
            return json.load(f)
    profs = paper_profiles(C.CNN_LAYERS, inner_layers=["conv1"])
    table = profile_table(profs, C.CNN_LAYERS)
    test_x, test_y = make_dataset(2048, seed=2, difficulty="hard")
    cfg = C.CNNConfig()
    shapes = C.cnn_weight_shapes(cfg)
    rows = {}
    params_by_profile = {}
    for i, prof in enumerate(profs):
        t0 = time.time()
        params = train_profile(i, table, steps=steps)
        params_by_profile[prof.name] = params
        acc = C.cnn_accuracy(params, jnp.asarray(table)[i], test_x, test_y)
        ab, wb = prof.bits["conv0"]
        if prof.name == "Mixed":
            ab, wb = 8, 8  # outer layers' precision (inner conv at 4)
        power_w, energy_j = profile_energy(prof.name, ab, wb)
        if prof.name == "Mixed":  # inner conv at A4-W4 → weighted activity
            p44, e44 = profile_energy("A4-W4", 4, 4)
            inner_share = (14 * 14 * 9 * 64 * 64) / (_CNN_MACS / 2)
            power_w = power_w * (1 - inner_share) + p44 * inner_share
            energy_j = power_w * CNN_LATENCY_S
        w_bytes = sum(
            int(np.prod(shapes[ln])) * min(prof.bits[ln][1], 16) // 8
            for ln in C.CNN_LAYERS)
        rows[prof.name] = {
            "accuracy_pct": round(acc * 100, 2),
            "latency_us": round(CNN_LATENCY_S * 1e6, 3),
            "weight_bytes": w_bytes,
            "power_w_model": round(power_w, 3),
            "energy_j_model": energy_j,
            "train_s": round(time.time() - t0, 1),
        }
        print(f"[table1] {prof.name:7s} acc {acc*100:5.2f}%  "
              f"P={power_w:.1f}W  bytes={w_bytes}")
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    result = {"rows": rows, "latency_us": CNN_LATENCY_S * 1e6,
              "paper_reference": PAPER_TABLE1}
    with open(CACHE, "w") as f:
        json.dump(result, f, indent=1)
    return result


def run_fig4(table1: dict) -> dict:
    """Merged adaptive engine (A8-W8 + Mixed) + battery simulation."""
    profs = paper_profiles(C.CNN_LAYERS, inner_layers=["conv1"])
    by_name = {p.name: p for p in profs}
    pair = [by_name["A8-W8"], by_name["Mixed"]]
    plan = merge_plan(pair)
    cfg = C.CNNConfig()
    res = plan.resource_bytes(C.cnn_weight_shapes(cfg))
    rows = table1["rows"]
    stats = [
        ProfileStats("A8-W8", rows["A8-W8"]["accuracy_pct"] / 100,
                     rows["A8-W8"]["energy_j_model"], CNN_LATENCY_S),
        ProfileStats("Mixed", rows["Mixed"]["accuracy_pct"] / 100,
                     rows["Mixed"]["energy_j_model"], CNN_LATENCY_S),
    ]
    # paper Fig.4 assumes a 10 Ah battery; in the model's µJ-per-inference
    # regime that is ≈2M most-accurate inferences worth of energy
    budget_j = stats[0].energy_j * 2_000_000
    adaptive = battery_simulation(stats, budget_j, accuracy_target=0.985,
                                  accuracy_floor=0.90, critical_every=10)
    fixed = battery_simulation(stats, budget_j, accuracy_target=0.985,
                               accuracy_floor=0.90, fixed_profile=0)
    out = {
        "merge": {
            "shared_layers": list(plan.shared_layers),
            "switched_layers": list(plan.switched_layers),
            "sharing_ratio": plan.sharing_ratio(),
            **{k: v for k, v in res.items()},
        },
        "power_saving_pct": round(
            100 * (1 - stats[1].energy_j / stats[0].energy_j), 2),
        "accuracy_drop_pct": round(
            rows["A8-W8"]["accuracy_pct"] - rows["Mixed"]["accuracy_pct"], 2),
        "battery": {"adaptive": adaptive, "non_adaptive": fixed,
                    "extra_classifications_pct": round(
                        100 * (adaptive["classifications"]
                               / max(1, fixed["classifications"]) - 1), 2)},
    }
    with open(os.path.join(ART, "repro", "fig4.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main(force: bool = False) -> None:
    t1 = run_table1(force=force)
    print(json.dumps({k: {kk: vv for kk, vv in v.items() if kk != "train_s"}
                      for k, v in t1["rows"].items()}, indent=1))
    f4 = run_fig4(t1)
    print(json.dumps(f4, indent=1))


if __name__ == "__main__":
    main()

"""Microbenchmarks for the Pallas kernels (CPU interpret-mode correctness +
reference-path wall time; TPU numbers come from deployment, not this box).

``derived`` columns report the structural wins that survive any backend:
HBM bytes of the weight operand vs bf16 (the memory-roofline lever).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantSpec, quantize_native
from repro.kernels import ref
from repro.kernels.ops import qmatmul_qt


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_qmatmul(m: int = 128, k: int = 1024, n: int = 1024) -> list[tuple]:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32) * 0.05
    rows = []
    bf16_bytes = k * n * 2
    for bits in (8, 4):
        spec = QuantSpec(bits=bits, per_channel=True, channel_axis=-1,
                         po2_scale=False)
        qt = quantize_native(w, spec)
        scale = jnp.asarray(qt.scale, jnp.float32).reshape(-1)
        ref_fn = jax.jit(lambda x_, d=qt.data, s=scale, b=bits:
                         ref.qmatmul_ref(x_, d, s, b))
        t_ref = _time(ref_fn, x)
        y_ref = ref_fn(x)
        y_kernel = qmatmul_qt(x, qt)
        err = float(jnp.max(jnp.abs(y_kernel - y_ref)))
        w_bytes = k * n * bits // 8
        rows.append((f"qmatmul_int{bits}_ref_path", t_ref,
                     f"w_bytes_ratio={w_bytes/bf16_bytes:.2f};kernel_err={err:.1e}"))
    return rows


def bench_qkv_attention(s: int = 1024, d: int = 64, hg: int = 4) -> list[tuple]:
    from repro.kernels.qkv_attention import qkv_attention_pallas
    key = jax.random.PRNGKey(1)
    g = 4
    q = jax.random.normal(key, (g, hg, d), jnp.float32)
    k_ = jax.random.normal(jax.random.fold_in(key, 1), (g, s, d), jnp.float32)
    v_ = jax.random.normal(jax.random.fold_in(key, 2), (g, s, d), jnp.float32)
    ks = jnp.abs(k_).max(axis=(1, 2)) / 127.0
    vs = jnp.abs(v_).max(axis=(1, 2)) / 127.0
    kq = jnp.clip(jnp.round(k_ / ks[:, None, None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v_ / vs[:, None, None]), -127, 127).astype(jnp.int8)
    lengths = jnp.full((g,), s, jnp.int32)

    def ref_fn():
        kf = jnp.broadcast_to((kq.astype(jnp.float32) * ks[:, None, None])[:, None],
                              (g, hg, s, d))
        vf = jnp.broadcast_to((vq.astype(jnp.float32) * vs[:, None, None])[:, None],
                              (g, hg, s, d))
        return ref.qkv_attention_ref(q[:, :, None, :], kf, vf, 1.0, 1.0)

    t_ref = _time(jax.jit(ref_fn))
    out_k = qkv_attention_pallas(q, kq, vq, ks, vs, lengths, block_s=256,
                                 interpret=True)
    out_r = ref_fn()[:, :, 0, :]
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    cache_ratio = 1 / 2  # int8 vs bf16 KV bytes
    return [(f"qkv_attention_int8_ref_path", t_ref,
             f"kv_bytes_ratio={cache_ratio:.2f};kernel_err={err:.1e}")]

"""Microbenchmarks for the Pallas kernels (CPU interpret-mode correctness +
reference-path wall time; TPU numbers come from deployment, not this box).

``derived`` columns report the structural wins that survive any backend:
HBM bytes of the weight operand vs bf16 (the memory-roofline lever), and —
for the paged-attention entry — the per-decode-step bytes the in-place
kernel moves vs the ``paged_view`` gather path it replaces.

  PYTHONPATH=src python benchmarks/kernel_bench.py [--smoke] [--json PATH]

``--smoke`` is the CI gate: asserts kernel/gather **token identity** on a
real ``decode_segment`` (both backends over the same paged pool) and that
the kernel path moves strictly fewer bytes per decode step; ``--json``
writes the rows plus the paged-attention byte accounting (the
``BENCH_*.json`` convention shared with ``serving_bench.py``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantSpec, quantize_native
from repro.kernels import ref
from repro.kernels.ops import qmatmul_qt


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_qmatmul(m: int = 128, k: int = 1024, n: int = 1024) -> list[tuple]:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32) * 0.05
    rows = []
    bf16_bytes = k * n * 2
    for bits in (8, 4):
        spec = QuantSpec(bits=bits, per_channel=True, channel_axis=-1,
                         po2_scale=False)
        qt = quantize_native(w, spec)
        scale = jnp.asarray(qt.scale, jnp.float32).reshape(-1)
        ref_fn = jax.jit(lambda x_, d=qt.data, s=scale, b=bits:
                         ref.qmatmul_ref(x_, d, s, b))
        t_ref = _time(ref_fn, x)
        y_ref = ref_fn(x)
        y_kernel = qmatmul_qt(x, qt)
        err = float(jnp.max(jnp.abs(y_kernel - y_ref)))
        w_bytes = k * n * bits // 8
        rows.append((f"qmatmul_int{bits}_ref_path", t_ref,
                     f"w_bytes_ratio={w_bytes/bf16_bytes:.2f};kernel_err={err:.1e}"))
    return rows


def bench_qkv_attention(s: int = 1024, d: int = 64, hg: int = 4) -> list[tuple]:
    from repro.kernels.qkv_attention import qkv_attention_pallas
    key = jax.random.PRNGKey(1)
    g = 4
    q = jax.random.normal(key, (g, hg, d), jnp.float32)
    k_ = jax.random.normal(jax.random.fold_in(key, 1), (g, s, d), jnp.float32)
    v_ = jax.random.normal(jax.random.fold_in(key, 2), (g, s, d), jnp.float32)
    ks = jnp.abs(k_).max(axis=(1, 2)) / 127.0
    vs = jnp.abs(v_).max(axis=(1, 2)) / 127.0
    kq = jnp.clip(jnp.round(k_ / ks[:, None, None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v_ / vs[:, None, None]), -127, 127).astype(jnp.int8)
    lengths = jnp.full((g,), s, jnp.int32)

    def ref_fn():
        kf = jnp.broadcast_to((kq.astype(jnp.float32) * ks[:, None, None])[:, None],
                              (g, hg, s, d))
        vf = jnp.broadcast_to((vq.astype(jnp.float32) * vs[:, None, None])[:, None],
                              (g, hg, s, d))
        return ref.qkv_attention_ref(q[:, :, None, :], kf, vf, 1.0, 1.0)

    t_ref = _time(jax.jit(ref_fn))
    out_k = qkv_attention_pallas(q, kq, vq, ks, vs, lengths, block_s=256,
                                 interpret=True)
    out_r = ref_fn()[:, :, 0, :]
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    cache_ratio = 1 / 2  # int8 vs bf16 KV bytes
    return [(f"qkv_attention_int8_ref_path", t_ref,
             f"kv_bytes_ratio={cache_ratio:.2f};kernel_err={err:.1e}")]


# ---------------------------------------------------------------------------
# paged attention: in-place kernel vs the paged_view gather path
# ---------------------------------------------------------------------------

def _paged_step_bytes(row_blocks, n_lblk, bs, hkv, d, esize, quantum):
    """Per-decode-step bytes moved by each backend, from the data layout.

    The structural quantity that survives any backend: what the step must
    *touch*. The gather path reads the dense ``[B, n_lblk*bs]`` view's K+V
    every step and pays the view build + exit fold-back (two more
    pool-sized round trips) once per ``quantum``-step segment; the kernel
    streams only the blocks each row actually maps — per-step traffic is
    proportional to live tokens, not provisioned capacity. ``esize`` is
    bytes per stored element: 2 (bf16), 1 (int8), 0.5 (packed int4 — two
    nibbles per byte).
    """
    b = len(row_blocks)
    view_kv = 2 * b * n_lblk * bs * hkv * d * esize      # K+V, dense view
    view_tidx = b * n_lblk * bs * 4
    gather = (view_kv + view_tidx) \
        + 2 * (view_kv + view_tidx) / quantum            # build + fold-back
    mapped = sum(row_blocks)
    kernel = 2 * mapped * bs * hkv * d * esize + mapped * bs * 4
    return {"gather_bytes_per_step": int(gather),
            "kernel_bytes_per_step": int(kernel),
            "bytes_ratio": kernel / gather}


def bench_paged_attention(n_blocks: int = 64, bs: int = 16, b: int = 8,
                          hkv: int = 2, hg: int = 2, d: int = 64,
                          quantum: int = 8, kv_bits: int = 16,
                          seed: int = 0) -> tuple[list[tuple], dict]:
    """Kernel vs gather-view path over one fragmented paged pool state.

    Rows hold ragged live lengths (the serving shape: most rows short, the
    pool provisioned for the long tail), so the kernel's mapped-blocks-only
    traffic is strictly below the dense view's. Returns CSV rows + the
    byte-accounting dict for ``--json`` / ``BENCH_*.json``.
    """
    from repro.kernels.paged_attention import paged_attention_pallas
    rng = np.random.default_rng(seed)
    n_lblk = n_blocks // b
    lens = [int(rng.integers(bs, min(3 * bs, n_lblk * bs))) for _ in range(b)]
    q = jnp.asarray(rng.normal(size=(b, hkv, hg, d)), jnp.float32)
    esize = {16: 2, 8: 1, 4: 0.5}[kv_bits]
    if kv_bits == 8:
        kp = jnp.asarray(rng.integers(-127, 128, (n_blocks, bs, hkv, d)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (n_blocks, bs, hkv, d)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(0.01, 0.1, (b, hkv)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.1, (b, hkv)), jnp.float32)
    elif kv_bits == 4:
        # int4 grids packed two-per-byte: the pool stores [.., D/2] int8
        from repro.core.qtypes import pack_int4
        kp = pack_int4(jnp.asarray(
            rng.integers(-7, 8, (n_blocks, bs, hkv, d)), jnp.int8))
        vp = pack_int4(jnp.asarray(
            rng.integers(-7, 8, (n_blocks, bs, hkv, d)), jnp.int8))
        ks = jnp.asarray(rng.uniform(0.05, 0.2, (b, hkv)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.05, 0.2, (b, hkv)), jnp.float32)
    else:
        kp = jnp.asarray(rng.normal(size=(n_blocks, bs, hkv, d)),
                         jnp.float32).astype(jnp.bfloat16)
        vp = kp * 0.5
        ks = vs = jnp.ones((b, hkv), jnp.float32)
    perm = rng.permutation(n_blocks)
    tidx = np.full((n_blocks, bs), -1, np.int32)
    bt = np.full((b, n_lblk), n_blocks, np.int32)
    pos = np.asarray([ln - 1 for ln in lens], np.int32)
    nxt = 0
    row_blocks = []
    for r, ln in enumerate(lens):
        nb_r = -(-ln // bs)
        row_blocks.append(nb_r)
        for lb in range(nb_r):
            p = int(perm[nxt]); nxt += 1
            bt[r, lb] = p
            nv = min(ln - lb * bs, bs)
            tidx[p, :nv] = lb * bs + np.arange(nv)
    tidx, bt, pos = jnp.asarray(tidx), jnp.asarray(bt), jnp.asarray(pos)

    import functools
    # jit over real array arguments — a zero-arg closure would constant-fold
    # the whole gather into the executable and time a buffer fetch
    gather_fn = jax.jit(functools.partial(ref.paged_attention_ref,
                                          bits=kv_bits))
    args = (q, kp, vp, ks, vs, tidx, bt, pos)
    t_gather = _time(gather_fn, *args)
    kernel_fn = functools.partial(paged_attention_pallas, bits=kv_bits,
                                  interpret=True)
    t_kernel = _time(kernel_fn, *args)
    err = float(jnp.max(jnp.abs(kernel_fn(*args) - gather_fn(*args))))

    byt = _paged_step_bytes(row_blocks, n_lblk, bs, hkv, d, esize, quantum)
    assert byt["kernel_bytes_per_step"] < byt["gather_bytes_per_step"], byt
    info = {
        "n_blocks": n_blocks, "block_size": bs, "batch": b,
        "kv_bits": kv_bits, "quantum": quantum,
        # K+V payload + token_idx metadata of one physical block — the
        # quantity that sets pool token capacity at a fixed byte budget
        "block_bytes": int(2 * bs * hkv * d * esize + bs * 4),
        "mapped_blocks": int(sum(row_blocks)),
        "tok_s_gather_ref": b / t_gather * 1e6,
        "tok_s_kernel_interpret": b / t_kernel * 1e6,
        "max_err_vs_gather": err,
        **byt,
    }
    rows = [(
        f"paged_attention_kv{kv_bits}_p{n_blocks}x{bs}", t_gather,
        f"kernel_bytes_per_step={byt['kernel_bytes_per_step']};"
        f"gather_bytes_per_step={byt['gather_bytes_per_step']};"
        f"bytes_ratio={byt['bytes_ratio']:.2f};kernel_err={err:.1e}")]
    return rows, info


def _smoke_token_identity() -> dict:
    """CI gate: one real ``decode_segment`` over one paged pool, decoded by
    both backends from identical state — emitted tokens must match exactly
    at kv16, kv8 and packed-kv4 (the kernel path replaces the gather path
    bit-for-bit at the token level, the serving contract)."""
    from repro.configs import get_smoke
    from repro.models import transformer as T

    cfg = get_smoke("granite-3-2b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    from repro.core.profiles import paper_profiles
    from repro.core.engine import AdaptiveEngine, QuantIndex
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b_: T.train_loss(p, cfg, br, b_))
    table = jnp.asarray(eng.table)
    out = {}
    for kv_bits in (16, 8, 4):
        b, slots, bs, steps = 4, 32, 8, 6
        n_lblk = slots // bs
        rng = np.random.default_rng(kv_bits)
        prompts = rng.integers(0, cfg.vocab, (b, 8)).astype(np.int32)
        bits = table[0]
        logits, rows = T.prefill(params, cfg, bits,
                                 {"tokens": jnp.asarray(prompts)}, slots,
                                 kv_bits=kv_bits)
        caches = T.init_paged_caches(cfg, b, slots, kv_bits=kv_bits,
                                     block_size=bs)
        # identity mapping: row r's logical block l -> physical r*n_lblk+l
        dest = np.arange(b * n_lblk, dtype=np.int32).reshape(b, n_lblk)
        kvp = caches["kv"]

        def blk(x):
            return x.reshape(cfg.n_layers, b, n_lblk, bs, *x.shape[3:])

        kvc = rows["kv"]
        caches["kv"] = kvp._replace(
            k=kvp.k.at[:, dest].set(blk(kvc.k)),
            v=kvp.v.at[:, dest].set(blk(kvc.v)),
            token_idx=kvp.token_idx.at[:, dest].set(blk(kvc.token_idx)),
            k_scale=kvc.k_scale, v_scale=kvc.v_scale,
            block_table=jnp.broadcast_to(
                jnp.asarray(dest)[None], (cfg.n_layers, b, n_lblk)))
        tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos0 = jnp.full((b,), prompts.shape[1], jnp.int32)
        rem = jnp.full((b,), steps, jnp.int32)
        sched = jnp.zeros((steps,), jnp.int32)
        toks = {}
        for backend in ("gather", "pallas"):
            # caches can be shared across the two eager, non-donating runs:
            # decode_segment is functional, both backends read the same
            # starting state
            ys, _, _, _, _ = T.decode_segment(
                params, cfg, table, sched, tok0, pos0, caches, rem,
                paged_backend=backend)
            toks[backend] = np.asarray(ys)
        assert np.array_equal(toks["gather"], toks["pallas"]), \
            f"kv{kv_bits}: kernel/gather token mismatch"
        out[f"kv{kv_bits}_tokens_match"] = True
    return out


def sweep_block_size(kv_bits: int = 4, pool_tokens: int = 1024,
                     sizes: tuple = (8, 16, 32)) -> dict:
    """Mini block-size sweep for the packed-kv4 kernel at equal pool tokens.

    Block size trades gather/view waste against per-block metadata and DMA
    granularity; the sweep holds the pool's token capacity fixed
    (``n_blocks * bs = pool_tokens``) and picks the size with the lowest
    kernel bytes per decode step — the config the ``--json`` payload
    persists for deployments to start from.
    """
    rows = []
    for bs in sizes:
        _, info = bench_paged_attention(n_blocks=pool_tokens // bs, bs=bs,
                                        kv_bits=kv_bits)
        rows.append({"block_size": bs,
                     "n_blocks": info["n_blocks"],
                     "kernel_bytes_per_step": info["kernel_bytes_per_step"],
                     "gather_bytes_per_step": info["gather_bytes_per_step"],
                     "block_bytes": info["block_bytes"],
                     "max_err_vs_gather": info["max_err_vs_gather"]})
    best = min(rows, key=lambda r: r["kernel_bytes_per_step"])
    return {"kv_bits": kv_bits, "pool_tokens": pool_tokens,
            "best_block_size": best["block_size"], "rows": rows}


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        description="Pallas kernel microbenchmarks. Emits "
                    "'name,us_per_call,derived' CSV rows; --json also "
                    "writes structured results (BENCH_*.json convention). "
                    "--smoke is the CI gate: kernel/gather token identity "
                    "on a real decode_segment + strictly-fewer bytes per "
                    "decode step for the paged-attention kernel.")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: token-identity + byte-accounting "
                         "assertions only (seconds-scale)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="write rows + paged-attention byte accounting as "
                         "JSON")
    ap.add_argument("--sweep-block-size", action="store_true",
                    help="kv4 block-size mini sweep (8/16/32) at equal "
                         "pool tokens; the best config (lowest kernel "
                         "bytes/step) is printed and persisted in --json")
    return ap.parse_args(argv)


def main(argv=None) -> None:
    args = _parse_args(argv)
    rows: list[tuple] = []
    paged_info: dict = {}
    if args.smoke:
        identity = _smoke_token_identity()
        for kv in (16, 8, 4):
            prows, info = bench_paged_attention(kv_bits=kv)
            rows += prows
            paged_info[f"kv{kv}"] = info
        paged_info["token_identity"] = identity
    else:
        rows += bench_qmatmul()
        rows += bench_qkv_attention()
        for kv in (16, 8, 4):
            prows, info = bench_paged_attention(kv_bits=kv)
            rows += prows
            paged_info[f"kv{kv}"] = info
    if "kv4" in paged_info and "kv8" in paged_info:
        k4, k8 = paged_info["kv4"], paged_info["kv8"]
        # packed-int4 contract at the reference pool point: strictly fewer
        # kernel bytes per step than kv8, and >= 1.5x pool token capacity
        # at equal block count + byte budget (2x payload minus the shared
        # token_idx metadata)
        assert k4["kernel_bytes_per_step"] < k8["kernel_bytes_per_step"], \
            (k4["kernel_bytes_per_step"], k8["kernel_bytes_per_step"])
        cap = k8["block_bytes"] / k4["block_bytes"]
        assert cap >= 1.5, f"kv4 token-capacity ratio {cap:.2f} < 1.5"
        paged_info["kv4_vs_kv8"] = {
            "kernel_bytes_per_step_ratio":
                k4["kernel_bytes_per_step"] / k8["kernel_bytes_per_step"],
            "token_capacity_x": cap,
        }
    if args.sweep_block_size:
        paged_info["block_size_sweep"] = sw = sweep_block_size()
        for r in sw["rows"]:
            rows.append((f"paged_attention_kv4_bs{r['block_size']}_sweep",
                         0.0,
                         f"kernel_bytes_per_step={r['kernel_bytes_per_step']};"
                         f"kernel_err={r['max_err_vs_gather']:.1e}"))
        print(f"# kv4 block-size sweep: best bs={sw['best_block_size']} "
              f"at {sw['pool_tokens']} pool tokens", file=sys.stderr)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.json:
        payload = {
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
            "paged_attention": paged_info,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"# json written to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()

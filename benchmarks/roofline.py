"""§Roofline: derive the three roofline terms per (arch × shape × mesh) from
the dry-run artifacts (brief §ROOFLINE ANALYSIS).

Conventions (documented in EXPERIMENTS.md):
* ``cost_analysis``/HLO parsing operate on the *per-device* post-SPMD module,
  so terms divide by per-chip peaks directly (global = per-device × chips).
* FLOPs/bytes/collective-bytes come from the depth-unrolled L∈{1,2}
  extrapolation (scan bodies are counted once by HloCostAnalysis — verified);
  ``memory_analysis`` comes from the full-depth production compile.
* MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode), with
  N_active for MoE. The ratio MODEL_FLOPS/HLO_FLOPS exposes remat/overhead.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import asdict

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.core.energy import TPU_V5E, roofline_terms
from repro.launch import specs as S
from repro.models import transformer as T

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful-FLOPs for the cell (global, per step)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    params = S.abstract_params(cfg)
    n = T.param_count(params)
    n_active = T.active_param_count(cfg, params)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token / sample


def load_cells(mesh: str = "pod1", suffix: str = "") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(ART, "dryrun",
                                           f"*__{mesh}{suffix}.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def roofline_row(rec: dict, hw=TPU_V5E) -> dict | None:
    if rec.get("status") != "ok":
        return None
    a = rec.get("analysis")
    if not a:
        return None
    chips = rec["devices"]
    f_dev = a["flops"]
    b_dev = a["bytes_accessed"]
    c_dev = a["collective_bytes"]["total"]
    terms = roofline_terms(f_dev * chips, b_dev * chips, c_dev * chips,
                           chips, hw)
    mf = model_flops(rec["arch"], rec["shape"])
    mem = rec.get("production", {}).get("memory", {}) or {}
    # structural HBM lower bound: parameters/optimizer/caches + step outputs
    # (``bytes_accessed`` on the unfused CPU HLO is the upper bound — on TPU,
    # fusion lands between the two; both are reported, EXPERIMENTS §Roofline)
    mem_lower_s = ((mem.get("argument_bytes", 0) + mem.get("output_bytes", 0))
                   / hw.hbm_bw)
    t_lower = max(terms["compute_s"], mem_lower_s, terms["collective_s"])
    row = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "flops_dev": f_dev, "bytes_dev": b_dev, "coll_dev": c_dev,
        **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "dominant", "t_step_s")},
        "memory_lower_s": mem_lower_s,
        "t_step_lower_s": t_lower,
        "model_flops": mf,
        "useful_ratio": mf / (f_dev * chips) if f_dev else 0.0,
        "roofline_fraction":
            terms["compute_s"] / terms["t_step_s"] if terms["t_step_s"] else 0.0,
        "roofline_fraction_struct":
            terms["compute_s"] / t_lower if t_lower else 0.0,
        "mem_bytes_per_dev": mem.get("argument_bytes"),
    }
    return row


def table(mesh: str = "pod1", suffix: str = "") -> list[dict]:
    rows = []
    for rec in load_cells(mesh, suffix):
        r = roofline_row(rec)
        if r:
            rows.append(r)
    return rows


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | chips | compute_s | mem_s(ub) | mem_s(struct) | "
           "coll_s | dominant | useful | frac(ub) | frac(struct) |\n|"
           + "---|" * 11)
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['memory_lower_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant'].split('_')[0]} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r['roofline_fraction_struct']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    rows = table("pod1")
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(render_markdown(rows))
    # skipped cells, for the record
    for rec in load_cells("pod1"):
        if rec.get("status") == "skipped":
            print(f"skipped: {rec['arch']} × {rec['shape']} — {rec['reason']}")


if __name__ == "__main__":
    main()

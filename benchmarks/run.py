"""Benchmark harness — one entry per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).

  table1_<profile>   — paper Table 1 analogue: modeled latency; derived =
                       accuracy%, modeled power, weight bytes
  fig3_<profile>     — accuracy-vs-energy Pareto points
  fig4_adaptive      — merged-engine overhead + battery simulation
  kernel_*           — Pallas kernel microbenches (interpret-validated)
  roofline_<cell>    — dry-run roofline step-time estimates (if artifacts exist)

Heavy QAT results are cached under artifacts/repro/ (delete to retrain);
roofline rows appear after ``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    rows: list[tuple] = []

    # --- paper tables (cached QAT) ---
    from benchmarks import repro_cnn
    t1 = repro_cnn.run_table1()
    for name, r in t1["rows"].items():
        rows.append((f"table1_{name}", r["latency_us"],
                     f"acc={r['accuracy_pct']}%;power_w={r['power_w_model']};"
                     f"w_bytes={r['weight_bytes']}"))
        rows.append((f"fig3_{name}", r["latency_us"],
                     f"acc={r['accuracy_pct']}%;energy_j={r['energy_j_model']:.3e}"))
    f4 = repro_cnn.run_fig4(t1)
    rows.append(("fig4_adaptive", t1["latency_us"],
                 f"overhead_vs_largest={f4['merge']['overhead_vs_largest']*100:.1f}%;"
                 f"power_saving={f4['power_saving_pct']}%;"
                 f"acc_drop={f4['accuracy_drop_pct']}%;"
                 f"extra_classifications={f4['battery']['extra_classifications_pct']}%"))

    # --- kernels ---
    from benchmarks import kernel_bench
    rows.extend(kernel_bench.bench_qmatmul())
    rows.extend(kernel_bench.bench_qkv_attention())

    # --- serving decode loop (fused scan vs per-token host loop) ---
    from benchmarks import serving_bench
    rows.extend(serving_bench.run(serving_bench.QUICK_POINTS, iters=2))

    # --- roofline (from dry-run artifacts when present) ---
    try:
        from benchmarks import roofline
        for r in roofline.table("pod1"):
            rows.append((f"roofline_{r['arch']}_{r['shape']}",
                         r["t_step_s"] * 1e6,
                         f"dominant={r['dominant'].split('_')[0]};"
                         f"useful_ratio={r['useful_ratio']:.2f}"))
    except Exception as e:  # artifacts absent → still a valid bench run
        rows.append(("roofline", 0.0, f"unavailable:{type(e).__name__}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
